//! TaskTracker: which tasks are ready, running, or done.
//!
//! A task becomes ready when all its input blocks are materialized
//! (present on the disk tier or in memory — *somewhere*, not necessarily
//! cached). Readiness is purely dataflow; the cache only affects speed.
//!
//! Two multi-job refinements sit on top of pure readiness:
//!
//! * **Gating** — an online job whose ingest barrier has not cleared yet
//!   buffers its ready tasks instead of exposing them to `pop_ready`
//!   ([`Self::gate_job`] / [`Self::ungate_job`]); the buffer flushes in
//!   readiness order, so a gated single job dispatches exactly like the
//!   classic all-at-once barrier run.
//! * **Priority** — the ready queue is ordered by (job priority
//!   descending, readiness sequence ascending). With every job at the
//!   default priority this is plain FIFO, byte-identical to the old
//!   `VecDeque` behaviour.

use crate::common::error::{EngineError, Result};
use crate::common::ids::{BlockId, JobId, TaskId};
use crate::dag::task::Task;
use std::collections::{BTreeMap, HashMap, HashSet};

#[derive(Debug, Default)]
pub struct TaskTracker {
    tasks: HashMap<TaskId, Task>,
    /// block -> tasks waiting on it.
    waiting: HashMap<BlockId, Vec<TaskId>>,
    /// task -> number of not-yet-materialized inputs.
    missing: HashMap<TaskId, usize>,
    /// Ready tasks keyed by (inverted job priority, readiness sequence):
    /// the first entry is the highest-priority, earliest-ready task.
    ready: BTreeMap<(u8, u64), TaskId>,
    ready_seq: u64,
    /// Tasks pushed into `ready` since the last [`Self::take_newly_ready`]
    /// drain (flight-recorder / queue-wait feed).
    newly_ready_log: Vec<TaskId>,
    /// Tasks handed out by `pop_ready`. A popped task can never re-enter
    /// the ready queue: with the spill tier on, an input may be dropped
    /// and re-materialized by lineage recompute *while its consumer is
    /// already dispatched* (drops, unlike kills, do not wait for a
    /// quiescent point) — without this guard the re-materialization
    /// would re-ready the in-flight task and it would dispatch twice.
    dispatched: HashSet<TaskId>,
    completed: HashSet<TaskId>,
    materialized: HashSet<BlockId>,
    /// block -> tasks producing it (one originally; recovery may add
    /// recompute clones with fresh ids).
    producers: HashMap<BlockId, Vec<TaskId>>,
    /// Remaining task count per job (drives job-completion times).
    per_job_remaining: HashMap<JobId, usize>,
    /// Dispatch priority per job (higher dispatches first; default 0).
    priority: HashMap<JobId, u8>,
    /// Jobs behind their ingest barrier: ready tasks buffer here (in
    /// readiness order) until the engine ungates the job.
    gated: HashMap<JobId, Vec<TaskId>>,
}

impl TaskTracker {
    /// Build from all jobs' tasks. `pre_materialized` are the input-dataset
    /// blocks that exist before any task runs (after ingest).
    pub fn new(tasks: Vec<Task>, pre_materialized: impl IntoIterator<Item = BlockId>) -> Self {
        let mut t = TaskTracker::default();
        t.add_tasks(tasks);
        for b in pre_materialized {
            t.on_block_materialized(b);
        }
        t
    }

    /// Queue a task that just became ready: into its job's gate buffer if
    /// the job is gated, else into the priority-ordered ready queue.
    fn push_ready(&mut self, tid: TaskId) {
        if self.dispatched.contains(&tid) {
            return;
        }
        let job = self.tasks[&tid].job;
        if let Some(buf) = self.gated.get_mut(&job) {
            buf.push(tid);
            return;
        }
        let prio = self.priority.get(&job).copied().unwrap_or(0);
        let key = (u8::MAX - prio, self.ready_seq);
        self.ready_seq += 1;
        self.ready.insert(key, tid);
        self.newly_ready_log.push(tid);
    }

    /// Drain the log of tasks that entered the ready queue since the last
    /// call (gate-buffered tasks appear once, when released). The engines
    /// use this for `task_ready` trace timestamps and queue-wait
    /// accounting; callers that don't drain pay one Vec push per task.
    pub fn take_newly_ready(&mut self) -> Vec<TaskId> {
        std::mem::take(&mut self.newly_ready_log)
    }

    /// Register additional tasks mid-run (online job admission, lineage
    /// recovery's recompute clones). Readiness respects the *current*
    /// materialized set. Task ids must be fresh.
    pub fn add_tasks(&mut self, tasks: Vec<Task>) {
        for task in tasks {
            debug_assert!(!self.tasks.contains_key(&task.id), "task {} re-added", task.id);
            *self.per_job_remaining.entry(task.job).or_default() += 1;
            let mut missing = 0;
            for b in &task.inputs {
                self.waiting.entry(*b).or_default().push(task.id);
                if !self.materialized.contains(b) {
                    missing += 1;
                }
            }
            self.producers.entry(task.output).or_default().push(task.id);
            self.missing.insert(task.id, missing);
            let id = task.id;
            self.tasks.insert(id, task);
            if missing == 0 {
                self.push_ready(id);
            }
        }
    }

    /// Set `job`'s dispatch priority (higher pops first). Call before the
    /// job's tasks are added — the key is computed at readiness time.
    pub fn set_priority(&mut self, job: JobId, priority: u8) {
        self.priority.insert(job, priority);
    }

    /// Buffer `job`'s ready tasks until [`Self::ungate_job`] — the online
    /// engines gate each job behind its own ingest barrier.
    pub fn gate_job(&mut self, job: JobId) {
        self.gated.entry(job).or_default();
    }

    /// Release a gated job: its buffered tasks enter the ready queue in
    /// the order they became ready.
    pub fn ungate_job(&mut self, job: JobId) {
        if let Some(buf) = self.gated.remove(&job) {
            for tid in buf {
                self.push_ready(tid);
            }
        }
    }

    pub fn is_gated(&self, job: JobId) -> bool {
        self.gated.contains_key(&job)
    }

    /// Has `job` completed every task registered for it so far? (False
    /// for unknown jobs.) Recovery uses this: a lost sink of a finished
    /// job has already been delivered and is not recomputed.
    pub fn job_complete(&self, job: JobId) -> bool {
        self.per_job_remaining.get(&job).is_some_and(|r| *r == 0)
    }

    pub fn task(&self, id: TaskId) -> Option<&Task> {
        self.tasks.get(&id)
    }

    pub fn is_materialized(&self, b: BlockId) -> bool {
        self.materialized.contains(&b)
    }

    /// A block became available; returns tasks that just became ready.
    /// Completed waiters are skipped — relevant only when a lost block
    /// re-materializes after recovery (their inputs were never un-lost).
    pub fn on_block_materialized(&mut self, b: BlockId) -> Vec<TaskId> {
        if !self.materialized.insert(b) {
            return vec![]; // already known
        }
        let mut newly_ready = vec![];
        if let Some(waiters) = self.waiting.get(&b) {
            for &tid in waiters {
                if self.completed.contains(&tid) {
                    continue;
                }
                let m = self.missing.get_mut(&tid).expect("tracked task");
                *m -= 1;
                // A dispatched (in-flight) waiter regains its input but
                // does not *become ready* — it is already running.
                if *m == 0 && !self.dispatched.contains(&tid) {
                    newly_ready.push(tid);
                }
            }
        }
        for &tid in &newly_ready {
            self.push_ready(tid);
        }
        newly_ready
    }

    /// A previously materialized block became unavailable (its durable
    /// copy died with a worker). Uncompleted waiters regain a missing
    /// input and leave the ready queue until the block re-materializes.
    pub fn on_block_lost(&mut self, b: BlockId) {
        if !self.materialized.remove(&b) {
            return;
        }
        if let Some(waiters) = self.waiting.get(&b) {
            for &tid in waiters {
                if self.completed.contains(&tid) {
                    continue;
                }
                let m = self.missing.get_mut(&tid).expect("tracked task");
                if *m == 0 {
                    // Not yet dispatched (the engines quiesce before a
                    // kill), so it must still be queued — in the ready
                    // queue or a gate buffer.
                    self.ready.retain(|_, t| *t != tid);
                    for buf in self.gated.values_mut() {
                        buf.retain(|t| *t != tid);
                    }
                }
                *m += 1;
            }
        }
    }

    /// Is some uncompleted task (original or recompute) going to produce
    /// `b`? Recovery uses this to avoid synthesizing duplicate producers.
    pub fn has_pending_producer(&self, b: BlockId) -> bool {
        self.producers
            .get(&b)
            .map(|ts| ts.iter().any(|t| !self.completed.contains(t)))
            .unwrap_or(false)
    }

    /// All blocks currently materialized (recovery scans this for the
    /// lost set; order is not significant).
    pub fn materialized_blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.materialized.iter().copied()
    }

    /// Pop the next ready task: highest job priority first, readiness
    /// order (FIFO) within a priority level. Gated jobs' tasks are not
    /// visible here.
    pub fn pop_ready(&mut self) -> Option<TaskId> {
        let tid = self.ready.pop_first().map(|(_, tid)| tid)?;
        self.dispatched.insert(tid);
        Some(tid)
    }

    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    /// Mark a task completed; materializes its output. Returns newly ready
    /// tasks plus `true` if this was its job's last task.
    pub fn on_task_complete(&mut self, id: TaskId) -> Result<(Vec<TaskId>, bool)> {
        let task = self
            .tasks
            .get(&id)
            .ok_or_else(|| EngineError::Invariant(format!("unknown task {id}")))?;
        if !self.completed.insert(id) {
            return Err(EngineError::Invariant(format!("task {id} completed twice")));
        }
        let job = task.job;
        let output = task.output;
        let newly_ready = self.on_block_materialized(output);
        let remaining = self
            .per_job_remaining
            .get_mut(&job)
            .expect("job counted at insert");
        *remaining -= 1;
        Ok((newly_ready, *remaining == 0))
    }

    pub fn all_done(&self) -> bool {
        self.completed.len() == self.tasks.len()
    }

    pub fn completed_len(&self) -> usize {
        self.completed.len()
    }

    pub fn total(&self) -> usize {
        self.tasks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{DatasetId, JobId};
    use crate::dag::graph::JobDag;
    use crate::dag::task::enumerate_tasks;

    fn two_stage() -> (Vec<Task>, Vec<BlockId>) {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 3, 1024);
        let b = dag.input("B", 3, 1024);
        let c = dag.zip("C", a, b);
        dag.aggregate("D", c);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        let inputs: Vec<BlockId> = dag
            .inputs()
            .flat_map(|d| d.blocks().collect::<Vec<_>>())
            .collect();
        (tasks, inputs)
    }

    #[test]
    fn zip_tasks_ready_after_inputs_materialize() {
        let (tasks, inputs) = two_stage();
        let mut tr = TaskTracker::new(tasks, vec![]);
        assert_eq!(tr.ready_len(), 0);
        for b in inputs {
            tr.on_block_materialized(b);
        }
        assert_eq!(tr.ready_len(), 3); // zip tasks only
        let t = tr.pop_ready().unwrap();
        assert!(tr.task(t).unwrap().kind == "zip_task");
    }

    #[test]
    fn completion_cascades_to_downstream_stage() {
        let (tasks, inputs) = two_stage();
        let zip0 = tasks[0].id;
        let mut tr = TaskTracker::new(tasks, inputs);
        let (ready, job_done) = tr.on_task_complete(zip0).unwrap();
        assert_eq!(ready.len(), 1); // agg task over C_0
        assert!(!job_done);
        assert!(tr.is_materialized(BlockId::new(DatasetId(2), 0)));
    }

    #[test]
    fn job_done_flag_on_last_task() {
        let (tasks, inputs) = two_stage();
        let ids: Vec<TaskId> = tasks.iter().map(|t| t.id).collect();
        let mut tr = TaskTracker::new(tasks, inputs);
        let mut last_flag = false;
        for id in ids {
            let (_, done) = tr.on_task_complete(id).unwrap();
            last_flag = done;
        }
        assert!(last_flag);
        assert!(tr.all_done());
    }

    #[test]
    fn double_completion_is_error() {
        let (tasks, inputs) = two_stage();
        let id = tasks[0].id;
        let mut tr = TaskTracker::new(tasks, inputs);
        tr.on_task_complete(id).unwrap();
        assert!(tr.on_task_complete(id).is_err());
    }

    #[test]
    fn lost_block_regates_waiters_and_recompute_unblocks() {
        let (tasks, inputs) = two_stage();
        let zip0 = tasks[0].clone();
        let mut tr = TaskTracker::new(tasks, inputs);
        tr.on_task_complete(zip0.id).unwrap(); // C_0 materialized, agg_0 ready
        let c0 = zip0.output;
        let ready_before = tr.ready_len();
        tr.on_block_lost(c0);
        assert!(!tr.is_materialized(c0));
        assert_eq!(tr.ready_len(), ready_before - 1, "agg_0 must leave the ready queue");
        // zip_0 completed -> no pending producer until a recompute is added.
        assert!(!tr.has_pending_producer(c0));
        let recompute = Task {
            id: TaskId(999),
            ..zip0.clone()
        };
        tr.add_tasks(vec![recompute]);
        assert!(tr.has_pending_producer(c0));
        assert_eq!(tr.ready_len(), ready_before, "recompute inputs are materialized");
        // Completing the recompute re-materializes C_0 and re-readies agg_0.
        let (ready, _) = tr.on_task_complete(TaskId(999)).unwrap();
        assert_eq!(ready.len(), 1);
        assert!(tr.is_materialized(c0));
    }

    #[test]
    fn rematerialization_skips_completed_waiters() {
        let (tasks, inputs) = two_stage();
        let zip0 = tasks[0].clone();
        let a0 = zip0.inputs[0];
        let mut tr = TaskTracker::new(tasks, inputs);
        tr.on_task_complete(zip0.id).unwrap();
        // Losing and re-materializing an input of the *completed* zip_0
        // must not underflow its missing count or re-ready it.
        tr.on_block_lost(a0);
        let ready = tr.on_block_materialized(a0);
        assert!(ready.is_empty());
        assert!(!tr.ready.values().any(|t| *t == zip0.id));
    }

    #[test]
    fn priority_orders_ready_queue_within_fifo() {
        let mut hi = JobDag::new(JobId(1), 10);
        let h = hi.input("H", 2, 1024);
        hi.aggregate("GH", h);
        let mut lo = JobDag::new(JobId(2), 20);
        let l = lo.input("L", 2, 1024);
        lo.aggregate("GL", l);
        let mut next = 0;
        let lo_tasks = enumerate_tasks(&lo, &mut next);
        let hi_tasks = enumerate_tasks(&hi, &mut next);
        let mut tr = TaskTracker::default();
        tr.set_priority(JobId(1), 5);
        tr.set_priority(JobId(2), 0);
        // Low-priority job's tasks become ready FIRST...
        tr.add_tasks(lo_tasks.clone());
        tr.add_tasks(hi_tasks.clone());
        for i in 0..2 {
            tr.on_block_materialized(BlockId::new(l, i));
            tr.on_block_materialized(BlockId::new(h, i));
        }
        // ...but the high-priority job still pops first, FIFO within it.
        let order: Vec<TaskId> = std::iter::from_fn(|| tr.pop_ready()).collect();
        assert_eq!(
            order,
            vec![hi_tasks[0].id, hi_tasks[1].id, lo_tasks[0].id, lo_tasks[1].id]
        );
    }

    #[test]
    fn gated_job_buffers_until_ungated_in_readiness_order() {
        let (tasks, inputs) = two_stage();
        let job = tasks[0].job;
        let mut tr = TaskTracker::default();
        tr.gate_job(job);
        tr.add_tasks(tasks);
        assert!(tr.is_gated(job));
        for b in inputs {
            tr.on_block_materialized(b);
        }
        // All zip tasks are dataflow-ready but the gate hides them.
        assert_eq!(tr.ready_len(), 0);
        tr.ungate_job(job);
        assert!(!tr.is_gated(job));
        assert_eq!(tr.ready_len(), 3);
        // Flush preserved readiness order.
        let t = tr.pop_ready().unwrap();
        assert!(tr.task(t).unwrap().kind == "zip_task");
    }

    #[test]
    fn rematerialization_never_re_readies_a_dispatched_task() {
        // Spill-tier scenario: agg_0's input C_0 is dropped and
        // recomputed while agg_0 is already in flight.
        let (tasks, inputs) = two_stage();
        let zip0 = tasks[0].clone();
        let mut tr = TaskTracker::new(tasks, inputs);
        tr.on_task_complete(zip0.id).unwrap(); // C_0 materialized, agg_0 ready
        let c0 = zip0.output;
        let agg0 = tr.pop_ready().unwrap(); // dispatched (we popped a zip first?)
        // Pop until we hold the agg task over C_0.
        let mut held = agg0;
        while tr.task(held).unwrap().inputs != vec![c0] {
            held = tr.pop_ready().unwrap();
        }
        tr.on_block_lost(c0);
        let recompute = Task {
            id: TaskId(999),
            ..zip0.clone()
        };
        tr.add_tasks(vec![recompute]);
        let ready_before = tr.ready_len();
        let (ready, _) = tr.on_task_complete(TaskId(999)).unwrap();
        assert!(ready.is_empty(), "in-flight agg_0 must not re-ready");
        assert_eq!(tr.ready_len(), ready_before);
        // The in-flight task still completes normally.
        tr.on_task_complete(held).unwrap();
    }

    #[test]
    fn job_complete_tracks_remaining() {
        let (tasks, inputs) = two_stage();
        let job = tasks[0].job;
        let ids: Vec<TaskId> = tasks.iter().map(|t| t.id).collect();
        let mut tr = TaskTracker::new(tasks, inputs);
        assert!(!tr.job_complete(job));
        assert!(!tr.job_complete(JobId(99)), "unknown job is not complete");
        for id in ids {
            tr.on_task_complete(id).unwrap();
        }
        assert!(tr.job_complete(job));
    }

    #[test]
    fn duplicate_materialization_is_idempotent() {
        let (tasks, inputs) = two_stage();
        let b0 = inputs[0];
        let mut tr = TaskTracker::new(tasks, inputs.clone());
        assert!(tr.on_block_materialized(b0).is_empty());
        assert_eq!(tr.ready_len(), 3);
    }
}
