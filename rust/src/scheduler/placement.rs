//! Block → worker placement.
//!
//! Index-aligned placement (`index % num_workers`) co-locates the aligned
//! inputs of binary ops (zip, join, zip_reduce) with their output — the
//! locality HDFS-style placement gives the paper's zip workload — while
//! coalesce's adjacent-index inputs land on different workers and exercise
//! the remote-read path.

use crate::common::ids::{BlockId, WorkerId};

/// Home worker of a block.
pub fn home_worker(block: BlockId, num_workers: u32) -> WorkerId {
    debug_assert!(num_workers > 0);
    WorkerId(block.index % num_workers)
}

/// Distinct home workers of a block set, sorted by worker index. The
/// home-routed control plane uses this to address the replicas of a peer
/// group (registration, retirement) without touching the rest of the
/// cluster.
pub fn homes_of(blocks: &[BlockId], num_workers: u32) -> Vec<WorkerId> {
    let mut ws: Vec<WorkerId> = blocks.iter().map(|b| home_worker(*b, num_workers)).collect();
    ws.sort_unstable();
    ws.dedup();
    ws
}

/// Which workers are up — the failure-aware view of placement.
///
/// Re-homing is *stable*: a block whose original [`home_worker`] is alive
/// keeps that home (its cached copy stays reachable and the home-routing
/// invariant undisturbed); only blocks orphaned by a kill probe forward,
/// deterministically, to the next alive worker. On restart the original
/// mapping returns (the driver purges the now-unreachable relocated
/// copies — DESIGN.md §3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AliveSet {
    up: Vec<bool>,
}

impl AliveSet {
    /// All `num_workers` workers up.
    pub fn new(num_workers: u32) -> Self {
        debug_assert!(num_workers > 0);
        Self {
            up: vec![true; num_workers as usize],
        }
    }

    /// First `initial` workers up, the rest of the `ceiling` slots
    /// *pending*: dead until a topology `Join` revives them (DESIGN.md
    /// §9). The placement modulus is the ceiling, so a pending slot's
    /// blocks probe forward exactly like a killed worker's — and a join
    /// moves only the blocks whose original home is the newcomer's slot.
    /// `with_pending(n, n)` is exactly [`AliveSet::new`].
    pub fn with_pending(initial: u32, ceiling: u32) -> Self {
        debug_assert!(initial > 0 && ceiling >= initial);
        let mut up = vec![true; ceiling as usize];
        for slot in up.iter_mut().skip(initial as usize) {
            *slot = false;
        }
        Self { up }
    }

    pub fn num_workers(&self) -> u32 {
        self.up.len() as u32
    }

    pub fn is_alive(&self, w: WorkerId) -> bool {
        self.up.get(w.0 as usize).copied().unwrap_or(false)
    }

    /// Mark `w` dead. Returns false if it already was.
    pub fn kill(&mut self, w: WorkerId) -> bool {
        std::mem::replace(&mut self.up[w.0 as usize], false)
    }

    /// Mark `w` alive again. Returns false if it already was.
    pub fn revive(&mut self, w: WorkerId) -> bool {
        let was = std::mem::replace(&mut self.up[w.0 as usize], true);
        !was
    }

    pub fn alive_count(&self) -> u32 {
        self.up.iter().filter(|&&u| u).count() as u32
    }

    pub fn alive_workers(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.up
            .iter()
            .enumerate()
            .filter(|(_, &u)| u)
            .map(|(i, _)| WorkerId(i as u32))
    }

    /// Failure-aware home: the original home if alive, else the next
    /// alive worker by index (wrapping). With every worker up this is
    /// exactly [`home_worker`]. Falls back to the original home when the
    /// whole cluster is down (degenerate; both engines abort with an
    /// `Invariant` error before routing against an empty cluster).
    pub fn home_of(&self, block: BlockId) -> WorkerId {
        let n = self.num_workers();
        let h = home_worker(block, n);
        if self.up[h.0 as usize] {
            return h;
        }
        for k in 1..n {
            let c = (h.0 + k) % n;
            if self.up[c as usize] {
                return WorkerId(c);
            }
        }
        h
    }

    /// Failure-aware [`homes_of`].
    pub fn homes_of(&self, blocks: &[BlockId]) -> Vec<WorkerId> {
        let mut ws: Vec<WorkerId> = blocks.iter().map(|b| self.home_of(*b)).collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    #[test]
    fn aligned_indices_co_locate() {
        let a = BlockId::new(DatasetId(0), 7);
        let b = BlockId::new(DatasetId(1), 7);
        let c = BlockId::new(DatasetId(2), 7);
        assert_eq!(home_worker(a, 4), home_worker(b, 4));
        assert_eq!(home_worker(a, 4), home_worker(c, 4));
    }

    #[test]
    fn coalesce_pairs_split_across_workers() {
        let a0 = BlockId::new(DatasetId(0), 0);
        let a1 = BlockId::new(DatasetId(0), 1);
        assert_ne!(home_worker(a0, 4), home_worker(a1, 4));
    }

    #[test]
    fn all_workers_used() {
        let homes: std::collections::HashSet<_> = (0..100)
            .map(|i| home_worker(BlockId::new(DatasetId(0), i), 4))
            .collect();
        assert_eq!(homes.len(), 4);
    }

    #[test]
    fn alive_set_rehoming_is_stable() {
        let b = |i: u32| BlockId::new(DatasetId(0), i);
        let mut alive = AliveSet::new(4);
        // Fully up: identical to the pure mapping.
        for i in 0..16 {
            assert_eq!(alive.home_of(b(i)), home_worker(b(i), 4));
        }
        assert!(alive.kill(WorkerId(2)));
        assert!(!alive.kill(WorkerId(2)), "double kill is a no-op");
        assert_eq!(alive.alive_count(), 3);
        // Blocks homed at survivors do not move.
        assert_eq!(alive.home_of(b(1)), WorkerId(1));
        assert_eq!(alive.home_of(b(3)), WorkerId(3));
        // Orphans probe forward to the next alive worker.
        assert_eq!(alive.home_of(b(2)), WorkerId(3));
        assert_eq!(alive.home_of(b(6)), WorkerId(3));
        // Revive restores the original mapping.
        assert!(alive.revive(WorkerId(2)));
        assert!(!alive.revive(WorkerId(2)));
        assert_eq!(alive.home_of(b(2)), WorkerId(2));
        let ws: Vec<u32> = alive.alive_workers().map(|w| w.0).collect();
        assert_eq!(ws, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pending_slots_start_dead_and_join_like_a_revive() {
        let b = |i: u32| BlockId::new(DatasetId(0), i);
        // 2 of 3 slots up: slot 2 is pending.
        let mut alive = AliveSet::with_pending(2, 3);
        assert_eq!(alive.num_workers(), 3, "modulus is the ceiling");
        assert_eq!(alive.alive_count(), 2);
        assert!(!alive.is_alive(WorkerId(2)));
        // Blocks originally homed at the pending slot probe forward...
        assert_eq!(alive.home_of(b(2)), WorkerId(0));
        assert_eq!(alive.home_of(b(0)), WorkerId(0));
        assert_eq!(alive.home_of(b(1)), WorkerId(1));
        // ...and return home when the slot joins; nothing else moves.
        assert!(alive.revive(WorkerId(2)));
        assert_eq!(alive.home_of(b(2)), WorkerId(2));
        assert_eq!(alive.home_of(b(0)), WorkerId(0));
        assert_eq!(alive.home_of(b(1)), WorkerId(1));
        // Degenerate elastic config is the fixed fleet.
        assert_eq!(AliveSet::with_pending(3, 3), AliveSet::new(3));
    }

    #[test]
    fn alive_homes_of_dedupes_over_survivors() {
        let b = |i: u32| BlockId::new(DatasetId(0), i);
        let mut alive = AliveSet::new(3);
        alive.kill(WorkerId(1));
        // Homes of {0, 1, 2}: 1 probes to 2.
        let ws: Vec<u32> = alive.homes_of(&[b(0), b(1), b(2)]).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![0, 2]);
    }

    #[test]
    fn homes_of_dedupes_and_sorts() {
        let b = |i: u32| BlockId::new(DatasetId(0), i);
        // indices 0..6 over 3 workers: homes {0, 1, 2}.
        let blocks: Vec<BlockId> = (0..6).map(b).collect();
        let ws: Vec<u32> = homes_of(&blocks, 3).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![0, 1, 2]);
        let ws: Vec<u32> = homes_of(&[b(4), b(1)], 3).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![1]);
        assert!(homes_of(&[], 3).is_empty());
    }
}
