//! Block → worker placement.
//!
//! Index-aligned placement (`index % num_workers`) co-locates the aligned
//! inputs of binary ops (zip, join, zip_reduce) with their output — the
//! locality HDFS-style placement gives the paper's zip workload — while
//! coalesce's adjacent-index inputs land on different workers and exercise
//! the remote-read path.

use crate::common::ids::{BlockId, WorkerId};

/// Home worker of a block.
pub fn home_worker(block: BlockId, num_workers: u32) -> WorkerId {
    debug_assert!(num_workers > 0);
    WorkerId(block.index % num_workers)
}

/// Distinct home workers of a block set, sorted by worker index. The
/// home-routed control plane uses this to address the replicas of a peer
/// group (registration, retirement) without touching the rest of the
/// cluster.
pub fn homes_of(blocks: &[BlockId], num_workers: u32) -> Vec<WorkerId> {
    let mut ws: Vec<WorkerId> = blocks.iter().map(|b| home_worker(*b, num_workers)).collect();
    ws.sort_unstable();
    ws.dedup();
    ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    #[test]
    fn aligned_indices_co_locate() {
        let a = BlockId::new(DatasetId(0), 7);
        let b = BlockId::new(DatasetId(1), 7);
        let c = BlockId::new(DatasetId(2), 7);
        assert_eq!(home_worker(a, 4), home_worker(b, 4));
        assert_eq!(home_worker(a, 4), home_worker(c, 4));
    }

    #[test]
    fn coalesce_pairs_split_across_workers() {
        let a0 = BlockId::new(DatasetId(0), 0);
        let a1 = BlockId::new(DatasetId(0), 1);
        assert_ne!(home_worker(a0, 4), home_worker(a1, 4));
    }

    #[test]
    fn all_workers_used() {
        let homes: std::collections::HashSet<_> = (0..100)
            .map(|i| home_worker(BlockId::new(DatasetId(0), i), 4))
            .collect();
        assert_eq!(homes.len(), 4);
    }

    #[test]
    fn homes_of_dedupes_and_sorts() {
        let b = |i: u32| BlockId::new(DatasetId(0), i);
        // indices 0..6 over 3 workers: homes {0, 1, 2}.
        let blocks: Vec<BlockId> = (0..6).map(b).collect();
        let ws: Vec<u32> = homes_of(&blocks, 3).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![0, 1, 2]);
        let ws: Vec<u32> = homes_of(&[b(4), b(1)], 3).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![1]);
        assert!(homes_of(&[], 3).is_empty());
    }
}
