//! DAG scheduling: readiness tracking and block/task placement.

pub mod placement;
pub mod tracker;

pub use placement::{home_worker, homes_of, AliveSet};
pub use tracker::TaskTracker;
