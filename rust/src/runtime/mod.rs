//! The compute runtime: loads AOT-compiled XLA artifacts (HLO text emitted
//! by `python/compile/aot.py`) and executes them via the PJRT CPU client.
//!
//! Python never runs here — this module only consumes `artifacts/*.hlo.txt`
//! plus `artifacts/manifest.tsv`. Each artifact is compiled **once** per
//! process and the loaded executable is reused for every task (the §Perf
//! "no per-task compile" rule).
//!
//! [`synthetic`] provides bit-equivalent pure-Rust implementations of every
//! task kind; they serve as the simulator's compute, the unit-test oracle,
//! and a numerics cross-check against the PJRT path.

pub mod manifest;
pub mod pjrt;
pub mod synthetic;
pub mod xla_stub;

pub use manifest::{ArtifactEntry, Manifest};
pub use pjrt::PjrtEngine;
pub use synthetic::SyntheticEngine;

use crate::common::error::Result;

/// Output of one task execution: payload block(s) plus the 4-float stats
/// vector every pipeline returns last.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskOutput {
    /// The materialized output block payload (first pipeline output,
    /// bit-cast to f32 if the artifact emits i32).
    pub payload: Vec<f32>,
    /// `[dot, sum_a, sum_b, max|a|+|b|]` checksum from the kernel.
    pub stats: [f32; 4],
}

/// A compute engine executes a task kind over input blocks.
///
/// Deliberately NOT `Send`: the PJRT engine is thread-pinned. Cross-thread
/// access goes through [`pjrt::ComputeHandle`].
pub trait ComputeEngine {
    /// Execute `kind` (e.g. "zip_task") at `block_len` over `inputs`.
    fn execute(&self, kind: &str, block_len: usize, inputs: &[&[f32]]) -> Result<TaskOutput>;

    fn name(&self) -> &'static str;
}
