//! Parser for `artifacts/manifest.tsv` (the offline-friendly twin of
//! `manifest.json`; see `python/compile/aot.py`).

use crate::common::error::{EngineError, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutputSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl OutputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT artifact: a (task kind, block length) pair.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub task: String,
    pub block_len: usize,
    pub file: PathBuf,
    pub arity: usize,
    pub outputs: Vec<OutputSpec>,
}

/// The full manifest, keyed by (task, block_len).
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: HashMap<(String, usize), ArtifactEntry>,
    pub num_parts: u32,
}

impl Manifest {
    /// Parse `<dir>/manifest.tsv`. Artifact paths are resolved to `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            EngineError::Manifest(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('#') {
                // Header carries num_parts=<n>.
                if let Some(pos) = line.find("num_parts=") {
                    m.num_parts = line[pos + "num_parts=".len()..]
                        .trim()
                        .parse()
                        .map_err(|e| EngineError::Manifest(format!("num_parts: {e}")))?;
                }
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                return Err(EngineError::Manifest(format!(
                    "line {}: expected 5 columns, got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let task = cols[0].to_string();
            let block_len: usize = cols[1].parse().map_err(|e| {
                EngineError::Manifest(format!("line {}: block_len: {e}", lineno + 1))
            })?;
            let arity: usize = cols[3]
                .parse()
                .map_err(|e| EngineError::Manifest(format!("line {}: arity: {e}", lineno + 1)))?;
            let outputs = cols[4]
                .split('|')
                .map(|spec| {
                    let (dtype, dims) = spec.split_once(':').ok_or_else(|| {
                        EngineError::Manifest(format!("line {}: bad output `{spec}`", lineno + 1))
                    })?;
                    let shape = dims
                        .split(',')
                        .filter(|d| !d.is_empty())
                        .map(|d| {
                            d.parse().map_err(|e| {
                                EngineError::Manifest(format!("line {}: dim: {e}", lineno + 1))
                            })
                        })
                        .collect::<Result<Vec<usize>>>()?;
                    Ok(OutputSpec {
                        dtype: dtype.to_string(),
                        shape,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            m.entries.insert(
                (task.clone(), block_len),
                ArtifactEntry {
                    task,
                    block_len,
                    file: dir.join(cols[2]),
                    arity,
                    outputs,
                },
            );
        }
        Ok(m)
    }

    pub fn get(&self, task: &str, block_len: usize) -> Result<&ArtifactEntry> {
        self.entries
            .get(&(task.to_string(), block_len))
            .ok_or_else(|| EngineError::ArtifactMissing(task.to_string(), block_len))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn block_lens(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.entries.keys().map(|(_, n)| *n).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# lerc-engine artifact manifest; num_parts=32
zip_task\t4096\tzip_task_4096.hlo.txt\t2\tfloat32:4096,2|float32:4
agg_task\t4096\tagg_task_4096.hlo.txt\t1\tfloat32:32|float32:4
partition_task\t65536\tpartition_task_65536.hlo.txt\t1\tint32:65536|float32:32|float32:4
";

    #[test]
    fn parses_entries_and_header() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.num_parts, 32);
        assert_eq!(m.len(), 3);
        let e = m.get("zip_task", 4096).unwrap();
        assert_eq!(e.arity, 2);
        assert_eq!(e.file, PathBuf::from("/a/zip_task_4096.hlo.txt"));
        assert_eq!(e.outputs[0].shape, vec![4096, 2]);
        assert_eq!(e.outputs[0].elems(), 8192);
        assert_eq!(e.outputs[1].shape, vec![4]);
    }

    #[test]
    fn int32_outputs_parse() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        let e = m.get("partition_task", 65536).unwrap();
        assert_eq!(e.outputs.len(), 3);
        assert_eq!(e.outputs[0].dtype, "int32");
    }

    #[test]
    fn missing_entry_is_typed() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        match m.get("zip_task", 999) {
            Err(EngineError::ArtifactMissing(t, n)) => {
                assert_eq!(t, "zip_task");
                assert_eq!(n, 999);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Manifest::parse("bad line no tabs", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tx\tf\t1\tfloat32:4", Path::new("/")).is_err());
        assert!(Manifest::parse("a\t4\tf\t1\tnocolon", Path::new("/")).is_err());
    }

    #[test]
    fn block_lens_sorted_unique() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.block_lens(), vec![4096, 65536]);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // Integration: the repo's own artifacts directory (built by
        // `make artifacts`). Skip silently when absent.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.len() >= 12);
        for kind in [
            "zip_task",
            "coalesce_task",
            "agg_task",
            "partition_task",
            "zip_reduce_task",
            "map_task",
        ] {
            for n in m.block_lens() {
                let e = m.get(kind, n).unwrap();
                assert!(e.file.exists(), "{:?}", e.file);
            }
        }
    }
}
