//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no XLA native library (and no crates.io
//! access beyond the baked-in registry), so the real `xla` crate cannot
//! be a dependency. This module mirrors the slice of its API that
//! [`crate::runtime::pjrt`] uses; every entry point fails cleanly at
//! `PjRtClient::cpu()`, which surfaces as [`crate::EngineError::Xla`]
//! when a run is configured with `ComputeMode::Pjrt`. The simulator,
//! tests and benches all use `ComputeMode::Synthetic` and never reach
//! this code. Swapping the stub for the real bindings is a one-line
//! change in `pjrt.rs`.

use std::fmt;
use std::path::Path;

/// Error type matching the real bindings' surface (`Display` only).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "XLA/PJRT runtime is not available in this build (offline stub); \
         use ComputeMode::Synthetic"
            .into(),
    ))
}

/// PJRT CPU client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Parsed HLO module (text format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, XlaError> {
        unavailable()
    }
}

/// A computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Host-side tensor literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

/// Element types extractable from a [`Literal`].
pub trait NativeType: Sized {}

impl NativeType for f32 {}
impl NativeType for i32 {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn literal_constructors_exist() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
