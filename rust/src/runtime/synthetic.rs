//! Pure-Rust reference implementations of every task kind — semantically
//! identical to the L1 Pallas kernels (`python/compile/kernels/`).
//!
//! Used as: the simulator's compute, the unit-test oracle, and the
//! numerics cross-check against the PJRT path
//! (`rust/tests/pjrt_crosscheck.rs`).

use super::{ComputeEngine, TaskOutput};
use crate::common::error::{EngineError, Result};

/// Lane width of the L1 kernels (TPU lane width).
pub const LANES: usize = 128;
/// Shuffle fan-out fixed at AOT time (must match model.NUM_PARTS).
pub const NUM_PARTS: i32 = 32;

/// `[dot(a,b), sum(a), sum(b), max(|a|+|b|)]` — matches kernels/zip_stats.
/// Accumulates in f64 to stay within float tolerance of XLA's tiled f32
/// accumulation regardless of order.
pub fn stats(a: &[f32], b: &[f32]) -> [f32; 4] {
    let mut dot = 0f64;
    let mut sa = 0f64;
    let mut sb = 0f64;
    let mut mx = f32::MIN;
    for (&x, &y) in a.iter().zip(b.iter()) {
        dot += x as f64 * y as f64;
        sa += x as f64;
        sb += y as f64;
        mx = mx.max(x.abs() + y.abs());
    }
    [dot as f32, sa as f32, sb as f32, mx]
}

/// Interleaved key/value pairs: matches `zip_pack(a, b).reshape(n, 2)`
/// row-major flattening.
pub fn zip_pack(a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(2 * a.len());
    for (&x, &y) in a.iter().zip(b.iter()) {
        out.push(x);
        out.push(y);
    }
    out
}

/// Concatenation: matches `coalesce_copy`.
pub fn coalesce(a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    out.extend_from_slice(a);
    out.extend_from_slice(b);
    out
}

/// 128-wide window sums: matches `window_sum`.
pub fn window_sum(x: &[f32]) -> Vec<f32> {
    x.chunks_exact(LANES)
        .map(|w| w.iter().map(|&v| v as f64).sum::<f64>() as f32)
        .collect()
}

/// MurmurHash3 fmix32 — bit-identical to kernels/hash_partition._mix32
/// (jnp int32 ops: arithmetic shifts, wrapping multiplies).
fn mix32(mut h: i32) -> i32 {
    h ^= h >> 16; // arithmetic shift, as in jnp int32
    h = h.wrapping_mul(-2048144789i32); // 0x85ebca6b
    h ^= h >> 13;
    h = h.wrapping_mul(-1028477387i32); // 0xc2b2ae35
    h ^= h >> 16;
    h
}

/// Elementwise affine map — matches kernels/scale_shift (scale=0.5,
/// shift=1.0 fixed at AOT time).
pub fn scale_shift(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v * 0.5 + 1.0).collect()
}

/// Partition ids as i32, bit-cast to f32 for uniform block storage.
/// `jnp.abs(h % p)` with Python modulo semantics == `rem_euclid` here
/// (jnp's `%` takes the divisor's sign, so the result is already >= 0).
pub fn hash_partition_ids(x: &[f32], num_parts: i32) -> Vec<f32> {
    x.iter()
        .map(|v| {
            let id = mix32(v.to_bits() as i32).rem_euclid(num_parts);
            f32::from_bits(id as u32)
        })
        .collect()
}

/// The synthetic compute engine: dispatches task kinds to the reference
/// functions above.
#[derive(Debug, Default, Clone)]
pub struct SyntheticEngine;

impl SyntheticEngine {
    pub fn new() -> Self {
        Self
    }
}

fn check_arity(kind: &str, want: usize, got: usize) -> Result<()> {
    if want != got {
        return Err(EngineError::Config(format!(
            "{kind}: expected {want} inputs, got {got}"
        )));
    }
    Ok(())
}

impl ComputeEngine for SyntheticEngine {
    fn execute(&self, kind: &str, block_len: usize, inputs: &[&[f32]]) -> Result<TaskOutput> {
        for (i, inp) in inputs.iter().enumerate() {
            if inp.len() != block_len {
                return Err(EngineError::Config(format!(
                    "{kind}: input {i} has {} elems, expected {block_len}",
                    inp.len()
                )));
            }
        }
        match kind {
            "zip_task" => {
                check_arity(kind, 2, inputs.len())?;
                Ok(TaskOutput {
                    payload: zip_pack(inputs[0], inputs[1]),
                    stats: stats(inputs[0], inputs[1]),
                })
            }
            "coalesce_task" => {
                check_arity(kind, 2, inputs.len())?;
                Ok(TaskOutput {
                    payload: coalesce(inputs[0], inputs[1]),
                    stats: stats(inputs[0], inputs[1]),
                })
            }
            "agg_task" => {
                check_arity(kind, 1, inputs.len())?;
                Ok(TaskOutput {
                    payload: window_sum(inputs[0]),
                    stats: stats(inputs[0], inputs[0]),
                })
            }
            "partition_task" => {
                check_arity(kind, 1, inputs.len())?;
                Ok(TaskOutput {
                    payload: hash_partition_ids(inputs[0], NUM_PARTS),
                    stats: stats(inputs[0], inputs[0]),
                })
            }
            "map_task" => {
                check_arity(kind, 1, inputs.len())?;
                Ok(TaskOutput {
                    payload: scale_shift(inputs[0]),
                    stats: stats(inputs[0], inputs[0]),
                })
            }
            "zip_reduce_task" => {
                check_arity(kind, 2, inputs.len())?;
                Ok(TaskOutput {
                    payload: window_sum(inputs[1]),
                    stats: stats(inputs[0], inputs[1]),
                })
            }
            other => Err(EngineError::Config(format!("unknown task kind `{other}`"))),
        }
    }

    fn name(&self) -> &'static str {
        "synthetic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize, offset: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32) * 0.25 + offset).collect()
    }

    #[test]
    fn zip_pack_interleaves() {
        let out = zip_pack(&[1.0, 2.0], &[10.0, 20.0]);
        assert_eq!(out, vec![1.0, 10.0, 2.0, 20.0]);
    }

    #[test]
    fn window_sum_sums_lanes() {
        let x = vec![1.0f32; 256];
        assert_eq!(window_sum(&x), vec![128.0, 128.0]);
    }

    #[test]
    fn stats_known_values() {
        let a = vec![1.0f32; 4];
        let b = vec![2.0f32; 4];
        let s = stats(&a, &b);
        assert_eq!(s, [8.0, 4.0, 8.0, 3.0]);
    }

    #[test]
    fn hash_ids_in_range_and_balanced() {
        let x = ramp(4096, -500.0);
        let ids: Vec<i32> = hash_partition_ids(&x, NUM_PARTS)
            .iter()
            .map(|v| v.to_bits() as i32)
            .collect();
        assert!(ids.iter().all(|&i| (0..NUM_PARTS).contains(&i)));
        let mut counts = [0u32; 32];
        for &i in &ids {
            counts[i as usize] += 1;
        }
        let expect = 4096 / 32;
        assert!(counts.iter().all(|&c| c > expect / 2 && c < expect * 2));
    }

    #[test]
    fn engine_dispatch_shapes() {
        let e = SyntheticEngine::new();
        let a = ramp(1024, 0.0);
        let b = ramp(1024, 1.0);
        let zip = e.execute("zip_task", 1024, &[&a, &b]).unwrap();
        assert_eq!(zip.payload.len(), 2048);
        let coal = e.execute("coalesce_task", 1024, &[&a, &b]).unwrap();
        assert_eq!(coal.payload.len(), 2048);
        let agg = e.execute("agg_task", 1024, &[&a]).unwrap();
        assert_eq!(agg.payload.len(), 8);
        let part = e.execute("partition_task", 1024, &[&a]).unwrap();
        assert_eq!(part.payload.len(), 1024);
        let zr = e.execute("zip_reduce_task", 1024, &[&a, &b]).unwrap();
        assert_eq!(zr.payload.len(), 8);
        assert_eq!(zr.payload, window_sum(&b));
    }

    #[test]
    fn engine_rejects_bad_arity_and_len() {
        let e = SyntheticEngine::new();
        let a = ramp(1024, 0.0);
        assert!(e.execute("zip_task", 1024, &[&a]).is_err());
        assert!(e.execute("agg_task", 512, &[&a]).is_err());
        assert!(e.execute("nope", 1024, &[&a]).is_err());
    }
}
