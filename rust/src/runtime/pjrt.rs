//! PJRT-backed compute: load HLO-text artifacts, compile once, execute on
//! the request path.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a `PjrtEngine` must stay on
//! the thread that created it. The threaded cluster driver therefore runs
//! one *compute service* thread owning the engine, and workers call it
//! through the cloneable [`ComputeHandle`] — the same device-executor
//! pattern a real serving stack uses.

use super::manifest::Manifest;
use super::{ComputeEngine, TaskOutput};
use crate::common::error::{EngineError, Result};
// The offline build has no XLA native library; the stub mirrors the real
// bindings' API and fails cleanly at client construction (see xla_stub).
use crate::runtime::xla_stub as xla;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

/// Lazily-compiled artifact executor. One per (task kind, block_len).
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: RefCell<HashMap<(String, usize), xla::PjRtLoadedExecutable>>,
}

impl PjrtEngine {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| EngineError::Xla(e.to_string()))?;
        Ok(Self {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile every artifact up front (otherwise compilation is lazy on
    /// first use). Returns the number compiled.
    pub fn warmup(&self) -> Result<usize> {
        let entries: Vec<(String, usize)> = self
            .manifest
            .block_lens()
            .into_iter()
            .flat_map(|n| {
                [
                    "zip_task",
                    "coalesce_task",
                    "agg_task",
                    "partition_task",
                    "zip_reduce_task",
                    "map_task",
                ]
                .into_iter()
                .filter(move |k| self.manifest.get(k, n).is_ok())
                .map(move |k| (k.to_string(), n))
            })
            .collect();
        for (kind, n) in &entries {
            self.ensure_compiled(kind, *n)?;
        }
        Ok(entries.len())
    }

    fn ensure_compiled(&self, kind: &str, block_len: usize) -> Result<()> {
        let key = (kind.to_string(), block_len);
        if self.executables.borrow().contains_key(&key) {
            return Ok(());
        }
        let entry = self.manifest.get(kind, block_len)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .map_err(|e| EngineError::Xla(format!("parse {:?}: {e}", entry.file)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| EngineError::Xla(format!("compile {kind}_{block_len}: {e}")))?;
        self.executables.borrow_mut().insert(key, exe);
        Ok(())
    }
}

impl ComputeEngine for PjrtEngine {
    fn execute(&self, kind: &str, block_len: usize, inputs: &[&[f32]]) -> Result<TaskOutput> {
        self.ensure_compiled(kind, block_len)?;
        let entry = self.manifest.get(kind, block_len)?;
        if inputs.len() != entry.arity {
            return Err(EngineError::Config(format!(
                "{kind}: expected {} inputs, got {}",
                entry.arity,
                inputs.len()
            )));
        }
        let exes = self.executables.borrow();
        let exe = exes
            .get(&(kind.to_string(), block_len))
            .expect("ensure_compiled populated");

        let args: Vec<xla::Literal> = inputs.iter().map(|x| xla::Literal::vec1(x)).collect();
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| EngineError::Xla(format!("execute {kind}: {e}")))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| EngineError::Xla(e.to_string()))?
            .to_tuple()
            .map_err(|e| EngineError::Xla(format!("untuple {kind}: {e}")))?;
        if tuple.len() != entry.outputs.len() {
            return Err(EngineError::Xla(format!(
                "{kind}: artifact returned {} outputs, manifest says {}",
                tuple.len(),
                entry.outputs.len()
            )));
        }

        // First output is the payload; last is the 4-float stats vector.
        let payload = literal_to_f32(&tuple[0], &entry.outputs[0].dtype)?;
        let stats_v = literal_to_f32(&tuple[tuple.len() - 1], "float32")?;
        if stats_v.len() != 4 {
            return Err(EngineError::Xla(format!(
                "{kind}: stats output has {} elems",
                stats_v.len()
            )));
        }
        Ok(TaskOutput {
            payload,
            stats: [stats_v[0], stats_v[1], stats_v[2], stats_v[3]],
        })
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Convert an output literal to the engine's uniform f32 payload storage
/// (i32 outputs are bit-cast, matching `synthetic::hash_partition_ids`).
fn literal_to_f32(lit: &xla::Literal, dtype: &str) -> Result<Vec<f32>> {
    match dtype {
        "float32" => lit
            .to_vec::<f32>()
            .map_err(|e| EngineError::Xla(e.to_string())),
        "int32" => Ok(lit
            .to_vec::<i32>()
            .map_err(|e| EngineError::Xla(e.to_string()))?
            .into_iter()
            .map(|v| f32::from_bits(v as u32))
            .collect()),
        other => Err(EngineError::Xla(format!("unsupported dtype {other}"))),
    }
}

// ---------------------------------------------------------------------
// Cross-thread compute service
// ---------------------------------------------------------------------

enum Request {
    Execute {
        kind: String,
        block_len: usize,
        inputs: Vec<Arc<[f32]>>,
        reply: mpsc::Sender<Result<TaskOutput>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to a compute engine running on its own thread.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::Sender<Request>,
}

impl ComputeHandle {
    /// Spawn a service thread running `make_engine()`'s engine. The factory
    /// runs *on the service thread* so non-`Send` engines (PJRT) work.
    pub fn spawn<F, E>(make_engine: F) -> Result<(Self, ComputeService)>
    where
        F: FnOnce() -> Result<E> + Send + 'static,
        E: ComputeEngine + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("lerc-compute".into())
            .spawn(move || {
                let engine = match make_engine() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute {
                            kind,
                            block_len,
                            inputs,
                            reply,
                        } => {
                            let refs: Vec<&[f32]> =
                                inputs.iter().map(|a| a.as_ref()).collect();
                            let _ = reply.send(engine.execute(&kind, block_len, &refs));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(EngineError::Io)?;
        ready_rx
            .recv()
            .map_err(|_| EngineError::ChannelClosed("compute service startup"))??;
        Ok((
            Self { tx },
            ComputeService {
                tx_shutdown: None,
                join: Some(join),
            },
        ))
    }

    /// Execute synchronously (blocks the calling worker thread, which is
    /// the semantics the engine wants: task compute is on-path).
    pub fn execute(
        &self,
        kind: &str,
        block_len: usize,
        inputs: Vec<Arc<[f32]>>,
    ) -> Result<TaskOutput> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                kind: kind.to_string(),
                block_len,
                inputs,
                reply,
            })
            .map_err(|_| EngineError::ChannelClosed("compute request"))?;
        rx.recv()
            .map_err(|_| EngineError::ChannelClosed("compute reply"))?
    }

    fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// Owns the service thread; joins on drop.
pub struct ComputeService {
    tx_shutdown: Option<ComputeHandle>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ComputeService {
    /// Attach the handle used for shutdown signaling.
    pub fn with_handle(mut self, h: ComputeHandle) -> Self {
        self.tx_shutdown = Some(h);
        self
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        if let Some(h) = self.tx_shutdown.take() {
            h.shutdown();
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SyntheticEngine;

    #[test]
    fn compute_service_round_trip() {
        let (handle, service) = ComputeHandle::spawn(|| Ok(SyntheticEngine::new())).unwrap();
        let _service = service.with_handle(handle.clone());
        let a: Arc<[f32]> = Arc::from(vec![1.0f32; 1024]);
        let b: Arc<[f32]> = Arc::from(vec![2.0f32; 1024]);
        let out = handle.execute("zip_task", 1024, vec![a, b]).unwrap();
        assert_eq!(out.payload.len(), 2048);
        assert_eq!(out.stats[0], 2048.0);
    }

    #[test]
    fn compute_service_propagates_errors() {
        let (handle, service) = ComputeHandle::spawn(|| Ok(SyntheticEngine::new())).unwrap();
        let _service = service.with_handle(handle.clone());
        let a: Arc<[f32]> = Arc::from(vec![1.0f32; 8]);
        assert!(handle.execute("zip_task", 8, vec![a]).is_err());
    }

    #[test]
    fn failed_factory_reports_at_spawn() {
        let r = ComputeHandle::spawn(|| -> Result<SyntheticEngine> {
            Err(EngineError::Config("boom".into()))
        });
        assert!(r.is_err());
    }
}
