//! Failure injection and lineage-based recovery.
//!
//! The failure model (DESIGN.md §3): killing a worker loses (a) every
//! block cached in its memory store and (b) the durable copies of the
//! *transform* blocks homed at it — task outputs are executor-local
//! spill, while ingest datasets live in replicated external storage
//! ([`DiskStore`](crate::storage::DiskStore)) and survive. Recovery then
//! (1) re-homes orphaned blocks over the surviving workers
//! ([`AliveSet`](crate::scheduler::placement::AliveSet) — stable probing,
//! so blocks whose home survived never move), (2) recomputes the minimal
//! ancestor closure of the lost-and-still-needed transform blocks
//! ([`lineage`]), and (3) repairs cache metadata: the
//! [`PeerTrackerMaster`](crate::peer::PeerTrackerMaster) invalidates
//! peer-groups that lost a cached member and the driver re-registers
//! groups / re-seeds ref and effective counts at the new homes, keeping
//! the DESIGN.md §1 home-routing invariant intact.
//!
//! [`plan_worker_loss`] is the engine-agnostic half, shared verbatim by
//! the threaded engine and the simulator so both lose and recover exactly
//! the same blocks for the same [`FailurePlan`]. The event-driven sim
//! core applies the plan synchronously inside the `OpComplete` handler
//! whose dispatch count crosses the trigger — never as its own event —
//! so same-instant kill/evict/admit ordering matches the legacy loop and
//! the recovered sets replay exactly (`tests/event_core_equiv.rs`).

pub mod lineage;
pub mod plan;

pub use lineage::{recovery_closure, synthesize_recompute_tasks, LineageIndex};
pub use plan::{
    AutoscaleConfig, FailureEvent, FailurePlan, RepairAction, TopologyEvent, TopologyPlan,
};

use crate::common::ids::{BlockId, WorkerId};
use crate::dag::analysis::RefCounts;
use crate::dag::task::Task;
use crate::scheduler::placement::AliveSet;
use crate::scheduler::TaskTracker;
use std::collections::HashSet;

/// Blocks with a recompute task planned but not yet re-materialized.
/// Attribution consults this to rank a blocking block `recomputing`
/// rather than `evicted`/`remote` while its lineage replay is in flight
/// (DESIGN.md §8). The driver owns it; workers read it through a shared
/// lock at attribution time only (tasks with whole groups never touch it).
#[derive(Debug, Default)]
pub struct RecomputeSet {
    planned: HashSet<BlockId>,
}

impl RecomputeSet {
    /// Register the outputs of freshly synthesized recompute tasks.
    pub fn plan(&mut self, tasks: &[Task]) {
        for t in tasks {
            self.planned.insert(t.output);
        }
    }

    /// A block re-materialized; its pending-recompute mark clears.
    pub fn materialized(&mut self, b: BlockId) {
        self.planned.remove(&b);
    }

    pub fn contains(&self, b: BlockId) -> bool {
        self.planned.contains(&b)
    }

    pub fn is_empty(&self) -> bool {
        self.planned.is_empty()
    }

    pub fn len(&self) -> usize {
        self.planned.len()
    }
}

/// What a worker kill costs and what recovery will do about it.
#[derive(Debug, Default)]
pub struct LossPlan {
    /// Materialized transform blocks whose durable copy died with the
    /// worker (un-materialized in the tracker; the threaded engine also
    /// deletes their files).
    pub lost_durable: Vec<BlockId>,
    /// Fresh tasks (new ids) recomputing the minimal ancestor closure,
    /// in topological order.
    pub recompute: Vec<Task>,
    /// Absolute ref-count updates caused by adding the recompute tasks.
    pub refcount_changes: Vec<(BlockId, u32)>,
}

impl LossPlan {
    /// Bytes the recompute tasks will re-materialize.
    pub fn recompute_bytes(&self) -> u64 {
        self.recompute.iter().map(|t| (t.output_len * 4) as u64).sum()
    }
}

/// The engine-agnostic kill bookkeeping: identify the durable blocks lost
/// with `worker` (homed at it under the pre-kill `alive` mapping),
/// un-materialize them, derive the minimal recompute closure, synthesize
/// fresh tasks and account their references. The caller applies the
/// engine-specific halves (store clear, disk deletes, peer-metadata
/// repair, scheduling) around this.
pub fn plan_worker_loss(
    worker: WorkerId,
    alive: &AliveSet,
    lineage: &LineageIndex,
    tasks: &[Task],
    tracker: &mut TaskTracker,
    refcounts: &mut RefCounts,
    next_task_id: &mut u64,
) -> LossPlan {
    let lost_durable: Vec<BlockId> = tracker
        .materialized_blocks()
        .filter(|&b| lineage.is_transform(b) && alive.home_of(b) == worker)
        .collect();
    for &b in &lost_durable {
        tracker.on_block_lost(b);
    }
    // Needed = still-referenced, or a result of a job that is still
    // running. A sink of a *completed* job was already delivered (its
    // completion time is on the record); recomputing it would tax the
    // surviving jobs for a result nobody is waiting on — the multi-job
    // scoping rule: lineage is rebuilt only for jobs that still need the
    // lost blocks. Skip anything an uncompleted task (original or prior
    // recompute) already produces.
    let roots: Vec<BlockId> = lost_durable
        .iter()
        .copied()
        .filter(|&b| {
            let live_sink = lineage.is_sink(b)
                && lineage
                    .producer_of(b)
                    .is_some_and(|ti| !tracker.job_complete(tasks[ti].job));
            (live_sink || refcounts.get(b) > 0) && !tracker.has_pending_producer(b)
        })
        .collect();
    let closure = recovery_closure(lineage, tasks, &roots, |b| {
        tracker.is_materialized(b) || tracker.has_pending_producer(b)
    });
    let recompute = synthesize_recompute_tasks(tasks, &closure, next_task_id);
    let refcount_changes = refcounts.add_tasks(&recompute);
    LossPlan {
        lost_durable,
        recompute,
        refcount_changes,
    }
}

/// The spill tier's analog of [`plan_worker_loss`] (DESIGN.md §5):
/// `dropped` blocks' bytes left both storage tiers (demotion refused, or
/// reclaimed from the spill area for budget room). Un-materialize the
/// ones a **pending task still needs** — their consumers leave the ready
/// queue until the bytes exist again — and derive their minimal lineage
/// recompute closure, exactly as for a failure-lost block. Dropped blocks
/// nobody will read again (reference count 0, no pending producer) are
/// abandoned, and sinks are never re-planned here: their bytes were
/// delivered to external storage by the async flush on completion, so a
/// cached-copy drop cannot un-deliver them (this is also what bounds the
/// drop → recompute → drop cycle).
///
/// Shared verbatim by the threaded engine and the simulator so both
/// re-plan exactly the same blocks for the same drop sequence.
pub fn plan_dropped_blocks(
    dropped: &[BlockId],
    lineage: &LineageIndex,
    tasks: &[Task],
    tracker: &mut TaskTracker,
    refcounts: &mut RefCounts,
    next_task_id: &mut u64,
) -> LossPlan {
    let needed: Vec<BlockId> = dropped
        .iter()
        .copied()
        .filter(|&b| {
            lineage.is_transform(b)
                && tracker.is_materialized(b)
                && refcounts.get(b) > 0
                && !tracker.has_pending_producer(b)
        })
        .collect();
    for &b in &needed {
        tracker.on_block_lost(b);
    }
    let closure = recovery_closure(lineage, tasks, &needed, |b| {
        tracker.is_materialized(b) || tracker.has_pending_producer(b)
    });
    let recompute = synthesize_recompute_tasks(tasks, &closure, next_task_id);
    let refcount_changes = refcounts.add_tasks(&recompute);
    LossPlan {
        lost_durable: needed,
        recompute,
        refcount_changes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{BlockId, JobId};
    use crate::dag::graph::JobDag;
    use crate::dag::task::enumerate_tasks;

    /// map(A) -> M, coalesce(M) -> X over 4 blocks, 2 workers: homes of
    /// M_i and X_i are i % 2; X_0 consumes M_0 (home 0) and M_1 (home 1).
    fn setup() -> (JobDag, Vec<Task>) {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 4, 1024);
        let m = dag.map("M", a);
        dag.coalesce("X", m);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        (dag, tasks)
    }

    #[test]
    fn recompute_set_tracks_planned_outputs() {
        let (_, tasks) = setup();
        let mut set = RecomputeSet::default();
        assert!(set.is_empty());
        set.plan(&tasks[..2]);
        assert_eq!(set.len(), 2);
        assert!(set.contains(tasks[0].output));
        set.materialized(tasks[0].output);
        assert!(!set.contains(tasks[0].output));
        assert_eq!(set.len(), 1);
    }

    #[test]
    fn loss_plan_recomputes_only_the_needed_closure() {
        let (dag, tasks) = setup();
        let lineage = LineageIndex::new(&tasks);
        let a = dag.datasets[0].id;
        let m = dag.datasets[1].id;
        let x = dag.datasets[2].id;
        let mut tracker = TaskTracker::new(tasks.clone(), (0..4).map(|i| BlockId::new(a, i)));
        let mut refcounts = RefCounts::from_tasks(&tasks);
        // Run everything except the last coalesce (X_1): the job is
        // still live when the kill lands.
        for t in tasks.iter().take(5) {
            refcounts.on_task_complete(t);
            tracker.on_task_complete(t.id).unwrap();
        }
        // Kill worker 0 of 2: loses M_0, M_2, X_0 (even indices).
        let alive = AliveSet::new(2);
        let mut next_id = 100;
        let plan = plan_worker_loss(
            WorkerId(0),
            &alive,
            &lineage,
            &tasks,
            &mut tracker,
            &mut refcounts,
            &mut next_id,
        );
        let mut lost = plan.lost_durable.clone();
        lost.sort();
        assert_eq!(
            lost,
            vec![BlockId::new(m, 0), BlockId::new(m, 2), BlockId::new(x, 0)]
        );
        // X_0 is a live job's sink -> recompute its coalesce, which needs
        // lost M_0 -> recompute its map. M_2 is still referenced by the
        // pending X_1 -> recompute its map. M_0 alone would NOT have
        // qualified (its consumer completed).
        let outputs: Vec<BlockId> = plan.recompute.iter().map(|t| t.output).collect();
        assert_eq!(
            outputs,
            vec![BlockId::new(m, 0), BlockId::new(m, 2), BlockId::new(x, 0)]
        );
        assert_eq!(plan.recompute_bytes(), (1024 + 1024 + 2048) * 4);
        // The recompute tasks are pending producers now; a second plan for
        // the same loss must not duplicate them.
        tracker.add_tasks(plan.recompute.clone());
        let plan2 = plan_worker_loss(
            WorkerId(0),
            &alive,
            &lineage,
            &tasks,
            &mut tracker,
            &mut refcounts,
            &mut next_id,
        );
        assert!(plan2.recompute.is_empty(), "{:?}", plan2.recompute);
    }

    #[test]
    fn dropped_blocks_replan_only_pending_consumers() {
        let (dag, tasks) = setup();
        let lineage = LineageIndex::new(&tasks);
        let a = dag.datasets[0].id;
        let m = dag.datasets[1].id;
        let x = dag.datasets[2].id;
        let mut tracker = TaskTracker::new(tasks.clone(), (0..4).map(|i| BlockId::new(a, i)));
        let mut refcounts = RefCounts::from_tasks(&tasks);
        // Maps done, coalesce X_0 done, X_1 pending: M_0/M_1 are consumed
        // (dead), M_2/M_3 still feed X_1, X_0 is a delivered sink.
        for t in tasks.iter().take(5) {
            refcounts.on_task_complete(t);
            tracker.on_task_complete(t.id).unwrap();
        }
        let mut next_id = 100;
        // Drop a dead block, a needed block, and a delivered sink at once.
        let plan = plan_dropped_blocks(
            &[BlockId::new(m, 0), BlockId::new(m, 2), BlockId::new(x, 0)],
            &lineage,
            &tasks,
            &mut tracker,
            &mut refcounts,
            &mut next_id,
        );
        assert_eq!(plan.lost_durable, vec![BlockId::new(m, 2)], "only the needed block");
        let outputs: Vec<BlockId> = plan.recompute.iter().map(|t| t.output).collect();
        assert_eq!(outputs, vec![BlockId::new(m, 2)]);
        assert!(!tracker.is_materialized(BlockId::new(m, 2)));
        assert!(tracker.is_materialized(BlockId::new(m, 0)), "dead drops stay materialized");
        assert!(tracker.is_materialized(BlockId::new(x, 0)), "sinks were delivered");
        // Re-dropping while the recompute is pending plans nothing more.
        tracker.add_tasks(plan.recompute.clone());
        let again = plan_dropped_blocks(
            &[BlockId::new(m, 2)],
            &lineage,
            &tasks,
            &mut tracker,
            &mut refcounts,
            &mut next_id,
        );
        assert!(again.recompute.is_empty());
        // Ingest drops never re-plan (durable external copies survive).
        let ing = plan_dropped_blocks(
            &[BlockId::new(a, 0)],
            &lineage,
            &tasks,
            &mut tracker,
            &mut refcounts,
            &mut next_id,
        );
        assert!(ing.lost_durable.is_empty() && ing.recompute.is_empty());
        assert!(tracker.is_materialized(BlockId::new(a, 0)));
    }

    #[test]
    fn completed_job_sinks_are_not_recomputed() {
        // Same geometry, but the job finishes before the kill: every
        // lost block is either unreferenced or a delivered result — the
        // plan must not tax the cluster for it (the multi-job scoping
        // rule; with several jobs, only the live ones rebuild lineage).
        let (dag, tasks) = setup();
        let lineage = LineageIndex::new(&tasks);
        let a = dag.datasets[0].id;
        let mut tracker = TaskTracker::new(tasks.clone(), (0..4).map(|i| BlockId::new(a, i)));
        let mut refcounts = RefCounts::from_tasks(&tasks);
        for t in &tasks {
            refcounts.on_task_complete(t);
            tracker.on_task_complete(t.id).unwrap();
        }
        assert!(tracker.job_complete(JobId(0)));
        let alive = AliveSet::new(2);
        let mut next_id = 100;
        let plan = plan_worker_loss(
            WorkerId(0),
            &alive,
            &lineage,
            &tasks,
            &mut tracker,
            &mut refcounts,
            &mut next_id,
        );
        assert_eq!(plan.lost_durable.len(), 3, "loss still recorded");
        assert!(plan.recompute.is_empty(), "{:?}", plan.recompute);
    }
}
