//! Deterministic, seedable failure injection.
//!
//! A [`FailurePlan`] is part of [`EngineConfig`](crate::common::config::EngineConfig)
//! and is interpreted identically by the threaded engine and the
//! simulator: each [`FailureEvent`] kills one worker when the driver's
//! global *dispatch index* reaches `at_dispatch`, optionally reviving it
//! `restart_after` dispatches later.
//!
//! The trigger is a dispatch count, not wall time, so the set of tasks
//! completed before the failure is a deterministic prefix of the dispatch
//! order: the driver stops dispatching at the trigger boundary, waits for
//! the in-flight tasks to drain (fail-stop detected at a scheduling
//! barrier), and only then applies the kill. Both engines therefore lose
//! exactly the same blocks for the same plan, which is what makes
//! fault-free vs. faulty runs byte-comparable (`rust/tests/recovery.rs`).
//!
//! [`TopologyPlan`] generalizes the schedule to elastic topology
//! (DESIGN.md §9): the same dispatch-indexed triggers and quiescent
//! points, plus `Join` events that bring pending worker slots online with
//! group-atomic warm-up migration, and an autoscale mode
//! ([`TopologyPlan::Auto`]) that derives joins and retires from
//! ready-queue depth and memory pressure instead of a fixed event list.

use crate::common::ids::WorkerId;
use crate::common::rng::SplitMix64;

/// One scheduled worker failure (and optional restart).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailureEvent {
    /// The worker to kill.
    pub worker: WorkerId,
    /// Fire once the driver has dispatched this many tasks (the kill is
    /// applied at the next quiescent point: dispatch held, in-flight
    /// drained). `0` kills the worker right after the ingest barrier.
    pub at_dispatch: u64,
    /// If set, revive the worker (empty caches, metadata re-seeded by the
    /// driver) after this many further task dispatches. A revive whose
    /// trigger exceeds the run's total dispatch count (including any
    /// recompute tasks) never fires: the run completes on the survivors
    /// and `RecoveryStats::workers_restarted` stays 0 — size triggers
    /// against the workload, as [`FailurePlan::seeded`] does. Killing
    /// every worker is an `Invariant` error, not a silent no-op.
    pub restart_after: Option<u64>,
}

/// A deterministic schedule of worker failures for one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FailurePlan {
    pub events: Vec<FailureEvent>,
}

impl FailurePlan {
    /// No failures — the default; both engines run their fault-free path.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Kill `worker` once `at_dispatch` tasks have been dispatched.
    pub fn kill_at(worker: u32, at_dispatch: u64) -> Self {
        Self {
            events: vec![FailureEvent {
                worker: WorkerId(worker),
                at_dispatch,
                restart_after: None,
            }],
        }
    }

    /// Add a restart `after` further dispatches to the last event.
    pub fn with_restart(mut self, after: u64) -> Self {
        if let Some(last) = self.events.last_mut() {
            last.restart_after = Some(after);
        }
        self
    }

    /// One seeded kill: a deterministic worker at a deterministic point
    /// in the middle third of the job (churn scenarios, property tests).
    pub fn seeded(seed: u64, num_workers: u32, total_tasks: u64) -> Self {
        let mut rng = SplitMix64::new(seed ^ 0xFA11_FA11);
        let worker = rng.next_below(num_workers.max(1) as u64) as u32;
        let span = (total_tasks / 3).max(1);
        let at = total_tasks / 3 + rng.next_below(span);
        Self::kill_at(worker, at)
    }

    /// Events sorted by trigger point (the order engines consume them).
    pub fn sorted_events(&self) -> Vec<FailureEvent> {
        let mut ev = self.events.clone();
        ev.sort_by_key(|e| e.at_dispatch);
        ev
    }

    /// The due-ordered `(trigger, action)` queue both engines consume:
    /// kills from the plan (events naming out-of-range workers are
    /// dropped); revives are inserted by the engine when the matching
    /// kill is applied.
    pub fn action_queue(&self, num_workers: u32) -> Vec<(u64, RepairAction)> {
        self.sorted_events()
            .into_iter()
            .filter(|e| e.worker.0 < num_workers)
            .map(|e| {
                (
                    e.at_dispatch,
                    RepairAction::Kill {
                        worker: e.worker,
                        restart_after: e.restart_after,
                    },
                )
            })
            .collect()
    }
}

/// One scheduled topology change: the elastic generalization of
/// [`FailureEvent`]. `Kill` keeps the failure-plan semantics exactly
/// (including the optional restart); `Join` brings a pending worker slot
/// online at a dispatch boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopologyEvent {
    /// Kill `worker` at `at_dispatch`, optionally reviving it
    /// `restart_after` further dispatches later — identical semantics to
    /// [`FailureEvent`].
    Kill {
        worker: WorkerId,
        at_dispatch: u64,
        restart_after: Option<u64>,
    },
    /// Worker `worker` joins the fleet once the driver has dispatched
    /// `at_dispatch` tasks, applied at the same quiescent point as a
    /// kill: dispatch held, in-flight drained. The joining id must name
    /// a *pending* slot (at or beyond the configured `num_workers`);
    /// joining an already-alive id is a config validation error.
    Join { worker: WorkerId, at_dispatch: u64 },
}

impl TopologyEvent {
    pub fn worker(&self) -> WorkerId {
        match self {
            TopologyEvent::Kill { worker, .. } | TopologyEvent::Join { worker, .. } => *worker,
        }
    }

    pub fn at_dispatch(&self) -> u64 {
        match self {
            TopologyEvent::Kill { at_dispatch, .. } | TopologyEvent::Join { at_dispatch, .. } => {
                *at_dispatch
            }
        }
    }
}

/// Cache-aware autoscaling policy ([`TopologyPlan::Auto`]): every
/// `check_every` dispatches the engine inspects ready-queue depth and
/// aggregate memory pressure at its quiescent gate and joins the
/// lowest-indexed pending slot (scale up) or retires the highest-indexed
/// alive worker (scale down). Decisions are deterministic functions of
/// modeled run state, so the simulator and the threaded engine scale at
/// the same dispatch boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// Never retire below this many alive workers.
    pub min_workers: u32,
    /// Never join beyond this many worker slots; also the fleet's
    /// placement ceiling (the [`AliveSet`] is sized to it, so joining a
    /// slot restores that slot's *original* homes rather than reshuffling
    /// the whole mapping).
    ///
    /// [`AliveSet`]: crate::scheduler::placement::AliveSet
    pub max_workers: u32,
    /// Dispatches between scale evaluations.
    pub check_every: u64,
    /// Ready-queue depth at or above which the fleet scales up.
    pub scale_up_ready: usize,
    /// Ready-queue depth at or below which a retire is allowed.
    pub scale_down_ready: usize,
    /// Alive-fleet memory utilization (used bytes / capacity) at or
    /// above which the fleet scales up.
    pub mem_high: f64,
    /// Utilization at or below which a retire is allowed.
    pub mem_low: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            min_workers: 1,
            max_workers: 8,
            check_every: 16,
            scale_up_ready: 8,
            scale_down_ready: 1,
            mem_high: 0.85,
            mem_low: 0.30,
        }
    }
}

/// A deterministic elastic-topology schedule — the API generalization of
/// [`FailurePlan`] (DESIGN.md §9). `Events` replays an explicit
/// dispatch-indexed list of kills/restarts/joins; `Auto` derives joins
/// and retires online from queue depth and memory pressure. Interpreted
/// identically by both engines at the failure path's quiescent points.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologyPlan {
    Events(Vec<TopologyEvent>),
    Auto(AutoscaleConfig),
}

impl Default for TopologyPlan {
    fn default() -> Self {
        TopologyPlan::Events(Vec::new())
    }
}

impl From<FailurePlan> for TopologyPlan {
    /// Lossless upgrade: every kill/restart keeps its trigger and
    /// semantics; the plan gains no joins, so the worker ceiling stays
    /// `num_workers` and behavior is identical to the failure path.
    fn from(p: FailurePlan) -> Self {
        TopologyPlan::Events(
            p.events
                .into_iter()
                .map(|e| TopologyEvent::Kill {
                    worker: e.worker,
                    at_dispatch: e.at_dispatch,
                    restart_after: e.restart_after,
                })
                .collect(),
        )
    }
}

impl TopologyPlan {
    /// Static topology — the default; both engines run their fixed-fleet
    /// path.
    pub fn none() -> Self {
        Self::default()
    }

    /// True only for an empty `Events` plan. An `Auto` plan is never
    /// empty: it always participates in the quiescent-gate machinery.
    pub fn is_empty(&self) -> bool {
        matches!(self, TopologyPlan::Events(ev) if ev.is_empty())
    }

    /// One join of `worker` once `at_dispatch` tasks have been dispatched.
    pub fn join_at(worker: u32, at_dispatch: u64) -> Self {
        TopologyPlan::Events(vec![TopologyEvent::Join {
            worker: WorkerId(worker),
            at_dispatch,
        }])
    }

    /// Kill parity with [`FailurePlan::kill_at`].
    pub fn kill_at(worker: u32, at_dispatch: u64) -> Self {
        FailurePlan::kill_at(worker, at_dispatch).into()
    }

    /// Append a further event to an `Events` plan (no-op on `Auto`).
    pub fn then(mut self, event: TopologyEvent) -> Self {
        if let TopologyPlan::Events(ev) = &mut self {
            ev.push(event);
        }
        self
    }

    pub fn autoscale(cfg: AutoscaleConfig) -> Self {
        TopologyPlan::Auto(cfg)
    }

    pub fn autoscale_config(&self) -> Option<&AutoscaleConfig> {
        match self {
            TopologyPlan::Auto(a) => Some(a),
            TopologyPlan::Events(_) => None,
        }
    }

    /// The fleet's worker-slot ceiling: every placement modulus, store
    /// vector, and trace track is sized to this up front, so a join is
    /// the placement analogue of a revive — only blocks whose *original*
    /// home is the newcomer's slot ever move to it (minimal re-homing).
    /// Plans without joins keep the ceiling at `num_workers`, leaving
    /// kill/restart-only behavior byte-identical to the failure path.
    pub fn ceiling(&self, num_workers: u32) -> u32 {
        match self {
            TopologyPlan::Events(ev) => ev
                .iter()
                .filter_map(|e| match e {
                    TopologyEvent::Join { worker, .. } => Some(worker.0 + 1),
                    TopologyEvent::Kill { .. } => None,
                })
                .fold(num_workers, u32::max),
            TopologyPlan::Auto(a) => num_workers.max(a.max_workers),
        }
    }

    /// Events sorted by trigger point (the order engines consume them).
    /// `Auto` plans schedule nothing up front.
    pub fn sorted_events(&self) -> Vec<TopologyEvent> {
        match self {
            TopologyPlan::Events(ev) => {
                let mut ev = ev.clone();
                ev.sort_by_key(|e| e.at_dispatch());
                ev
            }
            TopologyPlan::Auto(_) => Vec::new(),
        }
    }

    /// The due-ordered `(trigger, action)` queue both engines consume —
    /// the topology generalization of [`FailurePlan::action_queue`].
    /// Kills naming workers at or beyond `ceiling` are dropped (failure-
    /// plan compatibility); joins are always in range by construction
    /// (the ceiling covers them). `Auto` plans contribute nothing here —
    /// the engine evaluates the policy at its periodic quiescent checks.
    pub fn action_queue(&self, ceiling: u32) -> Vec<(u64, RepairAction)> {
        self.sorted_events()
            .into_iter()
            .filter(|e| e.worker().0 < ceiling)
            .map(|e| match e {
                TopologyEvent::Kill {
                    worker,
                    at_dispatch,
                    restart_after,
                } => (
                    at_dispatch,
                    RepairAction::Kill {
                        worker,
                        restart_after,
                    },
                ),
                TopologyEvent::Join { worker, at_dispatch } => {
                    (at_dispatch, RepairAction::Join { worker })
                }
            })
            .collect()
    }
}

/// A due topology-plan step, applied by an engine at its next quiescent
/// point. Shared by the threaded driver and the simulator so kill,
/// restart, and join semantics cannot drift between them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RepairAction {
    Kill {
        worker: WorkerId,
        restart_after: Option<u64>,
    },
    Revive {
        worker: WorkerId,
    },
    /// A pending worker slot comes online: the engine marks it alive,
    /// re-seeds its cache metadata, and warm-migrates the minimal
    /// re-homed block set to it group-atomically (DESIGN.md §9).
    Join {
        worker: WorkerId,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_at_builds_single_event() {
        let p = FailurePlan::kill_at(2, 10).with_restart(5);
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].worker, WorkerId(2));
        assert_eq!(p.events[0].at_dispatch, 10);
        assert_eq!(p.events[0].restart_after, Some(5));
        assert!(!p.is_empty());
        assert!(FailurePlan::none().is_empty());
    }

    #[test]
    fn seeded_is_deterministic_and_mid_job() {
        let a = FailurePlan::seeded(7, 4, 90);
        let b = FailurePlan::seeded(7, 4, 90);
        assert_eq!(a, b);
        let e = &a.events[0];
        assert!(e.worker.0 < 4);
        assert!((30..60).contains(&e.at_dispatch), "{}", e.at_dispatch);
    }

    #[test]
    fn action_queue_filters_invalid_workers_and_sorts() {
        let p = FailurePlan {
            events: vec![
                FailureEvent {
                    worker: WorkerId(9), // out of range for a 4-node cluster
                    at_dispatch: 1,
                    restart_after: None,
                },
                FailureEvent {
                    worker: WorkerId(2),
                    at_dispatch: 7,
                    restart_after: Some(3),
                },
            ],
        };
        let q = p.action_queue(4);
        assert_eq!(q.len(), 1);
        assert_eq!(
            q[0],
            (
                7,
                RepairAction::Kill {
                    worker: WorkerId(2),
                    restart_after: Some(3),
                }
            )
        );
    }

    #[test]
    fn topology_plan_upgrades_failure_plans_losslessly() {
        let p: TopologyPlan = FailurePlan::kill_at(2, 10).with_restart(5).into();
        assert_eq!(
            p,
            TopologyPlan::Events(vec![TopologyEvent::Kill {
                worker: WorkerId(2),
                at_dispatch: 10,
                restart_after: Some(5),
            }])
        );
        // No joins: the ceiling stays at num_workers and the action
        // queue matches the failure path's exactly.
        assert_eq!(p.ceiling(4), 4);
        assert_eq!(
            p.action_queue(4),
            FailurePlan::kill_at(2, 10).with_restart(5).action_queue(4)
        );
        assert!(TopologyPlan::none().is_empty());
        assert!(!TopologyPlan::Auto(AutoscaleConfig::default()).is_empty());
        assert!(TopologyPlan::from(FailurePlan::none()).is_empty());
    }

    #[test]
    fn ceiling_covers_join_ids_and_autoscale_max() {
        let p = TopologyPlan::join_at(5, 8);
        assert_eq!(p.ceiling(4), 6, "join of slot 5 needs 6 slots");
        assert_eq!(TopologyPlan::join_at(1, 8).ceiling(4), 4, "in-range join");
        let auto = TopologyPlan::Auto(AutoscaleConfig {
            max_workers: 10,
            ..Default::default()
        });
        assert_eq!(auto.ceiling(4), 10);
        assert_eq!(auto.ceiling(12), 12, "never below num_workers");
        assert!(auto.autoscale_config().is_some());
        assert!(p.autoscale_config().is_none());
    }

    #[test]
    fn topology_action_queue_orders_mixed_kills_and_joins() {
        let p = TopologyPlan::join_at(4, 9).then(TopologyEvent::Kill {
            worker: WorkerId(1),
            at_dispatch: 3,
            restart_after: Some(2),
        });
        let q = p.action_queue(p.ceiling(4));
        assert_eq!(
            q,
            vec![
                (
                    3,
                    RepairAction::Kill {
                        worker: WorkerId(1),
                        restart_after: Some(2),
                    }
                ),
                (9, RepairAction::Join { worker: WorkerId(4) }),
            ]
        );
        // Auto plans schedule nothing up front.
        assert!(TopologyPlan::Auto(AutoscaleConfig::default())
            .action_queue(8)
            .is_empty());
    }

    #[test]
    fn sorted_events_orders_by_trigger() {
        let p = FailurePlan {
            events: vec![
                FailureEvent {
                    worker: WorkerId(1),
                    at_dispatch: 20,
                    restart_after: None,
                },
                FailureEvent {
                    worker: WorkerId(0),
                    at_dispatch: 5,
                    restart_after: Some(1),
                },
            ],
        };
        let ev = p.sorted_events();
        assert_eq!(ev[0].at_dispatch, 5);
        assert_eq!(ev[1].at_dispatch, 20);
    }
}
