//! Deterministic, seedable failure injection.
//!
//! A [`FailurePlan`] is part of [`EngineConfig`](crate::common::config::EngineConfig)
//! and is interpreted identically by the threaded engine and the
//! simulator: each [`FailureEvent`] kills one worker when the driver's
//! global *dispatch index* reaches `at_dispatch`, optionally reviving it
//! `restart_after` dispatches later.
//!
//! The trigger is a dispatch count, not wall time, so the set of tasks
//! completed before the failure is a deterministic prefix of the dispatch
//! order: the driver stops dispatching at the trigger boundary, waits for
//! the in-flight tasks to drain (fail-stop detected at a scheduling
//! barrier), and only then applies the kill. Both engines therefore lose
//! exactly the same blocks for the same plan, which is what makes
//! fault-free vs. faulty runs byte-comparable (`rust/tests/recovery.rs`).

use crate::common::ids::WorkerId;
use crate::common::rng::SplitMix64;

/// One scheduled worker failure (and optional restart).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailureEvent {
    /// The worker to kill.
    pub worker: WorkerId,
    /// Fire once the driver has dispatched this many tasks (the kill is
    /// applied at the next quiescent point: dispatch held, in-flight
    /// drained). `0` kills the worker right after the ingest barrier.
    pub at_dispatch: u64,
    /// If set, revive the worker (empty caches, metadata re-seeded by the
    /// driver) after this many further task dispatches. A revive whose
    /// trigger exceeds the run's total dispatch count (including any
    /// recompute tasks) never fires: the run completes on the survivors
    /// and `RecoveryStats::workers_restarted` stays 0 — size triggers
    /// against the workload, as [`FailurePlan::seeded`] does. Killing
    /// every worker is an `Invariant` error, not a silent no-op.
    pub restart_after: Option<u64>,
}

/// A deterministic schedule of worker failures for one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FailurePlan {
    pub events: Vec<FailureEvent>,
}

impl FailurePlan {
    /// No failures — the default; both engines run their fault-free path.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Kill `worker` once `at_dispatch` tasks have been dispatched.
    pub fn kill_at(worker: u32, at_dispatch: u64) -> Self {
        Self {
            events: vec![FailureEvent {
                worker: WorkerId(worker),
                at_dispatch,
                restart_after: None,
            }],
        }
    }

    /// Add a restart `after` further dispatches to the last event.
    pub fn with_restart(mut self, after: u64) -> Self {
        if let Some(last) = self.events.last_mut() {
            last.restart_after = Some(after);
        }
        self
    }

    /// One seeded kill: a deterministic worker at a deterministic point
    /// in the middle third of the job (churn scenarios, property tests).
    pub fn seeded(seed: u64, num_workers: u32, total_tasks: u64) -> Self {
        let mut rng = SplitMix64::new(seed ^ 0xFA11_FA11);
        let worker = rng.next_below(num_workers.max(1) as u64) as u32;
        let span = (total_tasks / 3).max(1);
        let at = total_tasks / 3 + rng.next_below(span);
        Self::kill_at(worker, at)
    }

    /// Events sorted by trigger point (the order engines consume them).
    pub fn sorted_events(&self) -> Vec<FailureEvent> {
        let mut ev = self.events.clone();
        ev.sort_by_key(|e| e.at_dispatch);
        ev
    }

    /// The due-ordered `(trigger, action)` queue both engines consume:
    /// kills from the plan (events naming out-of-range workers are
    /// dropped); revives are inserted by the engine when the matching
    /// kill is applied.
    pub fn action_queue(&self, num_workers: u32) -> Vec<(u64, RepairAction)> {
        self.sorted_events()
            .into_iter()
            .filter(|e| e.worker.0 < num_workers)
            .map(|e| {
                (
                    e.at_dispatch,
                    RepairAction::Kill {
                        worker: e.worker,
                        restart_after: e.restart_after,
                    },
                )
            })
            .collect()
    }
}

/// A due failure-plan step, applied by an engine at its next quiescent
/// point. Shared by the threaded driver and the simulator so kill and
/// restart semantics cannot drift between them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RepairAction {
    Kill {
        worker: WorkerId,
        restart_after: Option<u64>,
    },
    Revive {
        worker: WorkerId,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_at_builds_single_event() {
        let p = FailurePlan::kill_at(2, 10).with_restart(5);
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].worker, WorkerId(2));
        assert_eq!(p.events[0].at_dispatch, 10);
        assert_eq!(p.events[0].restart_after, Some(5));
        assert!(!p.is_empty());
        assert!(FailurePlan::none().is_empty());
    }

    #[test]
    fn seeded_is_deterministic_and_mid_job() {
        let a = FailurePlan::seeded(7, 4, 90);
        let b = FailurePlan::seeded(7, 4, 90);
        assert_eq!(a, b);
        let e = &a.events[0];
        assert!(e.worker.0 < 4);
        assert!((30..60).contains(&e.at_dispatch), "{}", e.at_dispatch);
    }

    #[test]
    fn action_queue_filters_invalid_workers_and_sorts() {
        let p = FailurePlan {
            events: vec![
                FailureEvent {
                    worker: WorkerId(9), // out of range for a 4-node cluster
                    at_dispatch: 1,
                    restart_after: None,
                },
                FailureEvent {
                    worker: WorkerId(2),
                    at_dispatch: 7,
                    restart_after: Some(3),
                },
            ],
        };
        let q = p.action_queue(4);
        assert_eq!(q.len(), 1);
        assert_eq!(
            q[0],
            (
                7,
                RepairAction::Kill {
                    worker: WorkerId(2),
                    restart_after: Some(3),
                }
            )
        );
    }

    #[test]
    fn sorted_events_orders_by_trigger() {
        let p = FailurePlan {
            events: vec![
                FailureEvent {
                    worker: WorkerId(1),
                    at_dispatch: 20,
                    restart_after: None,
                },
                FailureEvent {
                    worker: WorkerId(0),
                    at_dispatch: 5,
                    restart_after: Some(1),
                },
            ],
        };
        let ev = p.sorted_events();
        assert_eq!(ev[0].at_dispatch, 5);
        assert_eq!(ev[1].at_dispatch, 20);
    }
}
