//! Lineage-based recomputation: which tasks must re-run after block loss.
//!
//! Spark recovers a lost partition by replaying the minimal slice of its
//! lineage; LRC's whole premise is that the same lineage graph drives
//! caching. This module computes that slice: given the set of lost
//! (previously materialized, now unavailable) blocks, walk ancestry in
//! the task graph and return the **minimal ancestor closure** — the
//! smallest set of producing tasks that re-materializes every lost block
//! that is still *needed*, under the rule that ingest (leaf) blocks are
//! never recomputed: they reload from [`DiskStore`](crate::storage::DiskStore)
//! (external replicated storage survives a worker).
//!
//! A lost block is *needed* when it still has unmaterialized consumers
//! (its reference count is positive — aggregated over every admitted
//! job) or it is a sink of a job that is still running. Lost
//! intermediates whose consumers all completed, and results of jobs that
//! already finished (their completion was delivered), are dead weight
//! and deliberately NOT recomputed — lineage rebuilds only for jobs that
//! still need the lost blocks; `rust/tests/proptest_lineage.rs`
//! property-tests both minimality and acyclicity of the closure.

use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, TaskId};
use crate::dag::task::Task;

/// Producer/consumer index over the tasks admitted so far. Online
/// multi-job runs grow it with [`Self::add_tasks`] at each admission —
/// jobs not yet admitted have no blocks to lose, so they are absent by
/// construction and a kill can never recompute on their behalf.
#[derive(Debug, Default)]
pub struct LineageIndex {
    /// Transform block → index (into the engine's task list) of its
    /// producer.
    producer: FxHashMap<BlockId, usize>,
    /// Blocks consumed by no task admitted so far (job results).
    sinks: FxHashSet<BlockId>,
    /// Blocks consumed by some admitted task (keeps sink-ness exact
    /// across incremental admissions).
    consumed: FxHashSet<BlockId>,
}

impl LineageIndex {
    /// Build from the original task enumeration (which is topological:
    /// producers precede consumers).
    pub fn new(tasks: &[Task]) -> Self {
        let mut idx = Self::default();
        idx.add_tasks(tasks, 0);
        idx
    }

    /// Extend the index with a newly admitted job's tasks, which occupy
    /// indices `offset..offset + tasks.len()` of the engine's task list
    /// (append-only, so earlier indices stay valid).
    pub fn add_tasks(&mut self, tasks: &[Task], offset: usize) {
        for (i, t) in tasks.iter().enumerate() {
            self.producer.insert(t.output, offset + i);
            if !self.consumed.contains(&t.output) {
                self.sinks.insert(t.output);
            }
        }
        for t in tasks {
            for b in &t.inputs {
                self.consumed.insert(*b);
                self.sinks.remove(b);
            }
        }
    }

    /// Is `b` produced by a task (false for ingest blocks)?
    pub fn is_transform(&self, b: BlockId) -> bool {
        self.producer.contains_key(&b)
    }

    /// Is `b` a job result no task consumes?
    pub fn is_sink(&self, b: BlockId) -> bool {
        self.sinks.contains(&b)
    }

    /// The producing task's index, if `b` is a transform block.
    pub fn producer_of(&self, b: BlockId) -> Option<usize> {
        self.producer.get(&b).copied()
    }
}

/// Compute the minimal ancestor closure for `roots` (the lost blocks that
/// must re-materialize). `available(b)` must return whether `b` can be
/// consumed without recomputation — it is materialized somewhere durable,
/// or an uncompleted task (original or a prior recompute) will produce
/// it. Returns indices into `tasks`, sorted ascending — task enumeration
/// is topological, so the closure is too.
pub fn recovery_closure(
    lineage: &LineageIndex,
    tasks: &[Task],
    roots: &[BlockId],
    available: impl Fn(BlockId) -> bool,
) -> Vec<usize> {
    let mut in_closure: FxHashSet<usize> = FxHashSet::default();
    let mut stack: Vec<BlockId> = roots.to_vec();
    while let Some(b) = stack.pop() {
        // Ingest blocks reload from external storage — no producer to run.
        let Some(ti) = lineage.producer_of(b) else {
            continue;
        };
        if !in_closure.insert(ti) {
            continue;
        }
        for &input in &tasks[ti].inputs {
            if lineage.is_transform(input) && !available(input) {
                stack.push(input);
            }
        }
    }
    let mut order: Vec<usize> = in_closure.into_iter().collect();
    order.sort_unstable();
    order
}

/// Clone the closure's tasks with fresh ids (the tracker refuses a second
/// completion of an already-completed id). Inputs, outputs, kinds and job
/// attribution are preserved, so a recompute produces byte-identical
/// blocks and re-triggers the same downstream readiness.
pub fn synthesize_recompute_tasks(
    tasks: &[Task],
    closure: &[usize],
    next_task_id: &mut u64,
) -> Vec<Task> {
    closure
        .iter()
        .map(|&i| {
            let id = TaskId(*next_task_id);
            *next_task_id += 1;
            Task {
                id,
                ..tasks[i].clone()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{DatasetId, JobId};
    use crate::dag::graph::JobDag;
    use crate::dag::task::enumerate_tasks;

    /// map(A) -> M, coalesce(M) -> X (the unaligned geometry that makes
    /// some lost blocks unneeded: M_i feeds X_{i/2} homed elsewhere).
    fn map_coalesce(blocks: u32) -> (JobDag, Vec<Task>) {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", blocks, 1024);
        let m = dag.map("M", a);
        dag.coalesce("X", m);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        (dag, tasks)
    }

    #[test]
    fn index_classifies_blocks() {
        let (dag, tasks) = map_coalesce(4);
        let idx = LineageIndex::new(&tasks);
        let a = dag.datasets[0].id;
        let m = dag.datasets[1].id;
        let x = dag.datasets[2].id;
        assert!(!idx.is_transform(BlockId::new(a, 0)));
        assert!(idx.is_transform(BlockId::new(m, 0)));
        assert!(idx.is_sink(BlockId::new(x, 0)));
        assert!(!idx.is_sink(BlockId::new(m, 0)));
        assert_eq!(idx.producer_of(BlockId::new(m, 2)), Some(2));
    }

    #[test]
    fn closure_recurses_through_lost_ancestors() {
        let (dag, tasks) = map_coalesce(4);
        let idx = LineageIndex::new(&tasks);
        let m = dag.datasets[1].id;
        let x = dag.datasets[2].id;
        // X_0 lost; its input M_0 also lost, M_1 available.
        let lost: FxHashSet<BlockId> =
            [BlockId::new(x, 0), BlockId::new(m, 0)].into_iter().collect();
        let closure =
            recovery_closure(&idx, &tasks, &[BlockId::new(x, 0)], |b| !lost.contains(&b));
        // map task for M_0 is index 0; coalesce task for X_0 is index 4.
        assert_eq!(closure, vec![0, 4]);
    }

    #[test]
    fn unneeded_lost_blocks_stay_out_of_the_closure() {
        let (dag, tasks) = map_coalesce(4);
        let idx = LineageIndex::new(&tasks);
        let m = dag.datasets[1].id;
        // M_2 lost but not a root (its consumer X_1 completed and X_1 is
        // not lost): nothing to recompute.
        let lost: FxHashSet<BlockId> = [BlockId::new(m, 2)].into_iter().collect();
        let closure = recovery_closure(&idx, &tasks, &[], |b| !lost.contains(&b));
        assert!(closure.is_empty());
    }

    #[test]
    fn incremental_add_tasks_matches_batch_build() {
        let (_, t1) = map_coalesce(4);
        let mut dag2 = JobDag::new(JobId(1), 10);
        let b = dag2.input("B", 2, 1024);
        dag2.aggregate("G", b);
        let mut next = t1.len() as u64;
        let t2 = enumerate_tasks(&dag2, &mut next);

        let mut all = t1.clone();
        all.extend(t2.clone());
        let batch = LineageIndex::new(&all);

        let mut inc = LineageIndex::default();
        inc.add_tasks(&t1, 0);
        inc.add_tasks(&t2, t1.len());

        for t in &all {
            assert_eq!(inc.producer_of(t.output), batch.producer_of(t.output));
            assert_eq!(inc.is_sink(t.output), batch.is_sink(t.output));
            assert_eq!(inc.is_transform(t.output), batch.is_transform(t.output));
        }
    }

    #[test]
    fn synthesized_tasks_get_fresh_ids_and_same_shape() {
        let (_, tasks) = map_coalesce(4);
        let mut next = 100;
        let re = synthesize_recompute_tasks(&tasks, &[0, 4], &mut next);
        assert_eq!(next, 102);
        assert_eq!(re[0].id, TaskId(100));
        assert_eq!(re[0].output, tasks[0].output);
        assert_eq!(re[0].inputs, tasks[0].inputs);
        assert_eq!(re[1].kind, tasks[4].kind);
        assert_eq!(re[1].job, tasks[4].job);
    }
}
