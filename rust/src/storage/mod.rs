//! The disk tiers: real block files plus a deterministic throttle model,
//! and the unified tiered read-cost API both engines charge through.

pub mod disk;
pub mod tiered;

pub use disk::DiskStore;
pub use tiered::{read_cost, spill_write_cost, TierSource};
