//! The disk tier: real block files plus a deterministic throttle model.

pub mod disk;

pub use disk::DiskStore;
