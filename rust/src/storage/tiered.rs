//! The tiered read-cost model: one place that prices a block read by the
//! tier that serves it.
//!
//! Before the spill tier existed, the simulator and the threaded engine
//! each re-implemented the §2 reload charge inline (memory reads priced
//! by `MemConfig`, disk reloads by `DiskConfig`), which is exactly the
//! kind of duplication a second storage tier would have tripled. Both
//! engines now route every input fetch — memory hit, remote hit, spill
//! read, durable reload — through [`read_cost`], so the cost model is
//! charged once, in one place, and the sim ≡ threaded equivalence on
//! *charges* is structural rather than coincidental.
//!
//! [`read_cost`] is the `NetModel::Flat` charge: an uncontended
//! closed-form price. Under `NetModel::FairShare` the event-driven
//! simulator keeps the local-memory component as a floor but replaces
//! the transfer component with contended link flows
//! (`sim::network`, DESIGN.md §6); the threaded engine always charges
//! flat.

use crate::common::config::EngineConfig;
use std::time::Duration;

/// Which tier served (or will serve) a block read.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TierSource {
    /// The reader's own worker's memory store (deserialization-bound).
    LocalMemory,
    /// Another worker's memory store (deserialization, floor of one
    /// network latency).
    RemoteMemory,
    /// A worker-local spill area (§2 disk model).
    SpilledLocal,
    /// The durable tier: replicated external storage for ingest datasets,
    /// async-flushed copies of task outputs (§2 disk model).
    Durable,
}

/// Modeled cost of reading `bytes` bytes from `source`.
pub fn read_cost(cfg: &EngineConfig, source: TierSource, bytes: u64) -> Duration {
    match source {
        TierSource::LocalMemory => cfg.mem.read_cost(bytes),
        TierSource::RemoteMemory => cfg.mem.read_cost(bytes).max(cfg.net.per_message_latency),
        TierSource::SpilledLocal | TierSource::Durable => cfg.disk.io_cost(bytes),
    }
}

/// Modeled cost of demoting `bytes` bytes into a spill area (a disk
/// write under the same §2 model).
pub fn spill_write_cost(cfg: &EngineConfig, bytes: u64) -> Duration {
    cfg.disk.io_cost(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{DiskConfig, MemConfig, NetConfig};

    fn cfg() -> EngineConfig {
        EngineConfig {
            mem: MemConfig {
                bandwidth_bytes_per_sec: 100 * 1024 * 1024,
            },
            disk: DiskConfig {
                bandwidth_bytes_per_sec: 100 * 1024 * 1024,
                seek_latency: Duration::from_millis(10),
                unthrottled: false,
            },
            net: NetConfig {
                per_message_latency: Duration::from_millis(50),
            },
            ..Default::default()
        }
    }

    #[test]
    fn memory_tiers_price_by_deserialization() {
        let c = cfg();
        let bytes = 100 * 1024 * 1024;
        assert_eq!(
            read_cost(&c, TierSource::LocalMemory, bytes),
            Duration::from_secs(1)
        );
        // Remote adds the network-latency floor (dominant for tiny reads).
        assert_eq!(
            read_cost(&c, TierSource::RemoteMemory, 1024),
            Duration::from_millis(50)
        );
        assert_eq!(
            read_cost(&c, TierSource::RemoteMemory, bytes),
            Duration::from_secs(1)
        );
    }

    #[test]
    fn disk_backed_tiers_share_the_seek_plus_transfer_model() {
        let c = cfg();
        let bytes = 100 * 1024 * 1024;
        let expect = Duration::from_millis(10) + Duration::from_secs(1);
        assert_eq!(read_cost(&c, TierSource::SpilledLocal, bytes), expect);
        assert_eq!(read_cost(&c, TierSource::Durable, bytes), expect);
        assert_eq!(spill_write_cost(&c, bytes), expect);
    }

    #[test]
    fn unthrottled_zeroes_disk_tiers_only() {
        let mut c = cfg();
        c.disk.unthrottled = true;
        assert_eq!(read_cost(&c, TierSource::SpilledLocal, 1 << 30), Duration::ZERO);
        assert_eq!(spill_write_cost(&c, 1 << 30), Duration::ZERO);
        assert!(read_cost(&c, TierSource::LocalMemory, 1 << 30) > Duration::ZERO);
    }
}
