//! DiskStore: the slow tier backing every materialized block.
//!
//! Blocks are real files (little-endian f32) under one directory, so the
//! engine round-trips genuine I/O; the *performance model* is the
//! configured throttle (`DiskConfig::io_cost`), because the paper's
//! testbed was a direct-I/O HDD while this host has an SSD + page cache
//! (see DESIGN.md §2). Callers are responsible for *paying* the returned
//! cost — the tokio engine sleeps it, the simulator advances its clock.

use crate::common::config::DiskConfig;
use crate::common::error::{EngineError, Result};
use crate::common::ids::BlockId;
use std::fs;
use std::path::{Path, PathBuf};
use std::time::Duration;

#[derive(Debug)]
pub struct DiskStore {
    dir: PathBuf,
    cfg: DiskConfig,
}

impl DiskStore {
    pub fn new(dir: impl AsRef<Path>, cfg: DiskConfig) -> Result<Self> {
        fs::create_dir_all(dir.as_ref())?;
        Ok(Self {
            dir: dir.as_ref().to_path_buf(),
            cfg,
        })
    }

    pub fn config(&self) -> &DiskConfig {
        &self.cfg
    }

    fn path_of(&self, b: BlockId) -> PathBuf {
        self.dir.join(format!("d{}_b{}.blk", b.dataset.0, b.index))
    }

    pub fn exists(&self, b: BlockId) -> bool {
        self.path_of(b).exists()
    }

    /// Write a block; returns the modeled I/O cost.
    ///
    /// Serialization is bulk little-endian: f32s are staged through a
    /// fixed chunk buffer and appended with `extend_from_slice`, instead
    /// of the old per-element `flat_map(to_le_bytes).collect()` whose
    /// byte-at-a-time iterator defeated the Vec's capacity pre-sizing.
    /// The file format is unchanged byte-for-byte (pinned by test).
    pub fn write(&self, b: BlockId, data: &[f32]) -> Result<Duration> {
        const CHUNK: usize = 1024;
        let mut bytes: Vec<u8> = Vec::with_capacity(data.len() * 4);
        let mut buf = [0u8; CHUNK * 4];
        for chunk in data.chunks(CHUNK) {
            for (i, v) in chunk.iter().enumerate() {
                buf[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
            }
            bytes.extend_from_slice(&buf[..chunk.len() * 4]);
        }
        fs::write(self.path_of(b), &bytes)?;
        Ok(self.cfg.io_cost(bytes.len() as u64))
    }

    /// Read a block; returns the payload and the modeled I/O cost.
    pub fn read(&self, b: BlockId) -> Result<(Vec<f32>, Duration)> {
        let bytes = fs::read(self.path_of(b)).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                EngineError::BlockNotFound(b)
            } else {
                EngineError::Io(e)
            }
        })?;
        if bytes.len() % 4 != 0 {
            return Err(EngineError::Invariant(format!(
                "block file {} has non-f32-aligned length {}",
                b,
                bytes.len()
            )));
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let cost = self.cfg.io_cost(bytes.len() as u64);
        Ok((data, cost))
    }

    pub fn delete(&self, b: BlockId) -> Result<()> {
        match fs::remove_file(self.path_of(b)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    /// Delete every block file (a worker kill wipes its local spill
    /// area — crash semantics: executor-local storage dies with the
    /// executor, so recovery's minimal-closure math never counts on it).
    pub fn wipe(&self) -> Result<usize> {
        let mut removed = 0;
        for entry in fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if path.extension().map(|x| x == "blk").unwrap_or(false) {
                fs::remove_file(path)?;
                removed += 1;
            }
        }
        Ok(removed)
    }

    /// Number of block files on disk (tests / reporting).
    pub fn block_count(&self) -> Result<usize> {
        Ok(fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map(|x| x == "blk").unwrap_or(false))
            .count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(7), i)
    }

    fn store() -> (crate::common::tempdir::TempDir, DiskStore) {
        let dir = crate::common::tempdir::TempDir::new("disk").unwrap();
        let cfg = DiskConfig {
            bandwidth_bytes_per_sec: 1024 * 1024,
            seek_latency: Duration::from_millis(5),
            unthrottled: false,
        };
        let s = DiskStore::new(dir.path(), cfg).unwrap();
        (dir, s)
    }

    #[test]
    fn round_trip_preserves_payload() {
        let (_d, s) = store();
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 100.0).collect();
        s.write(b(1), &data).unwrap();
        let (got, _) = s.read(b(1)).unwrap();
        assert_eq!(got, data);
    }

    #[test]
    fn io_cost_matches_model() {
        let (_d, s) = store();
        let data = vec![0f32; 1024 * 256]; // 1 MiB
        let wcost = s.write(b(2), &data).unwrap();
        let (_, rcost) = s.read(b(2)).unwrap();
        let expect = Duration::from_millis(5) + Duration::from_secs(1);
        assert_eq!(wcost, expect);
        assert_eq!(rcost, expect);
    }

    #[test]
    fn missing_block_is_typed_error() {
        let (_d, s) = store();
        match s.read(b(99)) {
            Err(EngineError::BlockNotFound(blk)) => assert_eq!(blk, b(99)),
            other => panic!("expected BlockNotFound, got {other:?}"),
        }
    }

    #[test]
    fn exists_delete_count() {
        let (_d, s) = store();
        assert!(!s.exists(b(1)));
        s.write(b(1), &[1.0, 2.0]).unwrap();
        s.write(b(2), &[3.0]).unwrap();
        assert!(s.exists(b(1)));
        assert_eq!(s.block_count().unwrap(), 2);
        s.delete(b(1)).unwrap();
        assert!(!s.exists(b(1)));
        s.delete(b(1)).unwrap(); // idempotent
        assert_eq!(s.block_count().unwrap(), 1);
    }

    #[test]
    fn wipe_clears_every_block_file() {
        let (_d, s) = store();
        s.write(b(1), &[1.0]).unwrap();
        s.write(b(2), &[2.0]).unwrap();
        assert_eq!(s.wipe().unwrap(), 2);
        assert_eq!(s.block_count().unwrap(), 0);
        assert!(!s.exists(b(1)));
        assert_eq!(s.wipe().unwrap(), 0, "idempotent");
    }

    /// The chunked bulk encoder must produce exactly the bytes the old
    /// per-element encoder did — the on-disk format is a compatibility
    /// surface (spill areas and durable copies survive across runs).
    #[test]
    fn write_is_byte_identical_to_per_element_encoding() {
        let (_d, s) = store();
        // Crosses several chunk boundaries and ends on a partial chunk;
        // includes non-finite and signed-zero bit patterns so the pin is
        // bit-exact, not just value-exact.
        let mut data: Vec<f32> = (0..2500).map(|i| (i as f32 * 0.37).sin() * 1e3).collect();
        data.extend([
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            0.0,
            f32::MIN_POSITIVE / 2.0, // subnormal
            f32::MAX,
        ]);
        s.write(b(3), &data).unwrap();
        let on_disk = fs::read(s.path_of(b(3))).unwrap();
        let reference: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(on_disk, reference);
        let (got, _) = s.read(b(3)).unwrap();
        assert_eq!(got.len(), data.len());
        for (g, d) in got.iter().zip(&data) {
            assert_eq!(g.to_bits(), d.to_bits());
        }
        // An empty payload writes an empty file.
        s.write(b(4), &[]).unwrap();
        assert_eq!(fs::read(s.path_of(b(4))).unwrap().len(), 0);
    }

    #[test]
    fn overwrite_replaces() {
        let (_d, s) = store();
        s.write(b(1), &[1.0, 2.0, 3.0]).unwrap();
        s.write(b(1), &[9.0]).unwrap();
        let (got, _) = s.read(b(1)).unwrap();
        assert_eq!(got, vec![9.0]);
    }
}
