//! Fixed-bucket log-scale latency histogram (DESIGN.md §8).
//!
//! 64 buckets, one per bit width of the recorded nanosecond value:
//! bucket 0 holds zero, bucket `i` holds values in `[2^(i-1), 2^i - 1]`.
//! Recording is two adds and never allocates; percentile queries return
//! the *upper bound* of the bucket the rank falls in, so reported
//! p50/p95/p99 are deterministic, conservative (never understate), and
//! within 2x of the true quantile — exactly the resolution a log-scale
//! latency summary needs. Dependency-free by design (the offline build
//! bakes in no hdrhistogram crate).

use std::fmt;
use std::time::Duration;

pub const BUCKETS: usize = 64;

/// Log2-bucketed histogram of nanosecond latencies.
#[derive(Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for LatencyHistogram {
    // `[u64; 64]` has no derived Default (arrays stop at 32): spell the
    // zero state out.
    fn default() -> Self {
        Self {
            buckets: [0u64; BUCKETS],
            count: 0,
            sum: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(nanos: u64) -> usize {
        (u64::BITS - nanos.leading_zeros()) as usize
    }

    /// Upper bound of bucket `i` (the value a percentile query reports).
    fn bucket_upper(i: usize) -> u64 {
        if i >= BUCKETS {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one latency in nanoseconds.
    pub fn record(&mut self, nanos: u64) {
        let i = Self::bucket_of(nanos).min(BUCKETS - 1);
        self.buckets[i] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(nanos);
    }

    /// Record one latency as a [`Duration`] (saturating at u64 nanos).
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean in nanoseconds (0 when empty).
    pub fn mean(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum / self.count
        }
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) as the upper bound of the bucket
    /// the rank lands in; 0 when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        // Rank of the queried element, 1-based, nearest-rank definition.
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

/// Render nanoseconds with a human-scale unit (`report::fleet_table`
/// cells and the trace summaries share this formatting).
pub fn fmt_nanos(nanos: u64) -> String {
    if nanos >= 1_000_000_000 {
        format!("{:.2}s", nanos as f64 / 1e9)
    } else if nanos >= 1_000_000 {
        format!("{:.2}ms", nanos as f64 / 1e6)
    } else if nanos >= 1_000 {
        format!("{:.2}us", nanos as f64 / 1e3)
    } else {
        format!("{nanos}ns")
    }
}

impl fmt::Debug for LatencyHistogram {
    // Compact: the full 64-bucket array would drown `{:?}` reports; the
    // derived form is also what the Off-is-byte-identical test compares.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.count)
            .field("mean", &self.mean())
            .field("p50", &self.p50())
            .field("p95", &self.p95())
            .field("p99", &self.p99())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0);
    }

    #[test]
    fn single_value_lands_in_its_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(1000); // 2^9 < 1000 < 2^10 - 1
        assert_eq!(h.count(), 1);
        assert_eq!(h.p50(), 1023);
        assert_eq!(h.p99(), 1023);
    }

    #[test]
    fn zero_is_representable() {
        let mut h = LatencyHistogram::new();
        h.record(0);
        assert_eq!(h.p50(), 0);
    }

    #[test]
    fn percentiles_are_monotonic_and_conservative() {
        let mut h = LatencyHistogram::new();
        for v in [10u64, 100, 1_000, 10_000, 100_000, 1_000_000] {
            for _ in 0..100 {
                h.record(v);
            }
        }
        assert!(h.p50() <= h.p95());
        assert!(h.p95() <= h.p99());
        // Conservative: the bucket upper bound never understates.
        assert!(h.p99() >= 1_000_000);
        assert!(h.p99() < 2_000_000);
    }

    #[test]
    fn merge_is_additive() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(5);
        b.record(500);
        b.record(500);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        let mut c = LatencyHistogram::new();
        c.record(5);
        c.record(500);
        c.record(500);
        assert_eq!(a, c);
    }

    #[test]
    fn duration_recording_matches_nanos() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_duration(Duration::from_micros(7));
        b.record(7_000);
        assert_eq!(a, b);
    }

    #[test]
    fn fmt_nanos_scales_units() {
        assert_eq!(fmt_nanos(15), "15ns");
        assert_eq!(fmt_nanos(1_500), "1.50us");
        assert_eq!(fmt_nanos(2_500_000), "2.50ms");
        assert_eq!(fmt_nanos(3_000_000_000), "3.00s");
    }
}
