//! Metrics: the paper's two cache metrics plus runtime and message
//! accounting, with report formatting for the experiment harness.
//!
//! * **cache hit ratio** — memory hits / block accesses (the conventional
//!   metric, Fig 6).
//! * **effective cache hit ratio** — *effective* hits / block accesses
//!   (the paper's metric, Def. 1, Fig 7). A task's input hits are
//!   effective iff **all** its peer blocks were served from memory.

pub mod attribution;
pub mod hist;
pub mod report;
pub mod timeline;

pub use attribution::{AttributionStats, IneffectiveCause, ServedFrom};
pub use hist::LatencyHistogram;
pub use timeline::{Timeline, TimelineSample};

use crate::common::ids::JobId;

use std::collections::BTreeMap;
use std::time::Duration;

/// Block-access accounting for one engine run (cluster-wide).
#[derive(Debug, Clone, Copy, Default)]
pub struct AccessStats {
    /// Total block reads by tasks.
    pub accesses: u64,
    /// Reads served from memory (any worker's cache).
    pub mem_hits: u64,
    /// Memory hits that were *effective* (all peers of the reading task
    /// were served from memory too).
    pub effective_hits: u64,
    /// Reads served from the disk tier.
    pub disk_reads: u64,
    /// Bytes read from disk.
    pub disk_bytes: u64,
    /// Reads served from a remote worker's memory.
    pub remote_hits: u64,
}

impl AccessStats {
    pub fn hit_ratio(&self) -> f64 {
        ratio(self.mem_hits, self.accesses)
    }

    pub fn effective_hit_ratio(&self) -> f64 {
        ratio(self.effective_hits, self.accesses)
    }

    pub fn merge(&mut self, other: &AccessStats) {
        self.accesses += other.accesses;
        self.mem_hits += other.mem_hits;
        self.effective_hits += other.effective_hits;
        self.disk_reads += other.disk_reads;
        self.disk_bytes += other.disk_bytes;
        self.remote_hits += other.remote_hits;
    }
}

/// Control-plane message accounting (paper §III-C overhead analysis).
///
/// Fan-out counters (`broadcast_deliveries`, `refcount_updates`) count
/// driver → worker *network* sends. A delivery to the worker that evicted
/// (or is home to) the block is **counted, not excluded**: the driver is
/// its own node, the worker's replica transitions only on the master's
/// authoritative broadcast (the report alone does not invalidate — the
/// master dedupes concurrent reports to one broadcast), so that send
/// crosses the wire like any other. `CtrlPlane::Broadcast` therefore
/// satisfies `broadcast_deliveries == invalidation_broadcasts × workers`
/// exactly (asserted in `tests/ctrl_plane.rs`); `CtrlPlane::HomeRouted`
/// counts only the interested-worker sends, so per-event deliveries
/// range from 1 to `workers`.
#[derive(Debug, Clone, Copy, Default)]
pub struct MessageStats {
    /// Worker → master eviction reports.
    pub eviction_reports: u64,
    /// Master → workers invalidation broadcasts (events, not fan-out).
    pub invalidation_broadcasts: u64,
    /// Fan-out deliveries of those broadcasts (events × recipients; all
    /// workers in Broadcast mode, interested workers in HomeRouted mode).
    pub broadcast_deliveries: u64,
    /// Driver → worker reference-count update messages. One per worker
    /// per completion in Broadcast mode; in HomeRouted mode a drain
    /// cycle's deltas coalesce into at most one message per home worker.
    pub refcount_updates: u64,
    /// Peer-profile registration broadcasts (one per job).
    pub profile_broadcasts: u64,
}

impl MessageStats {
    /// Messages attributable to the LERC protocol (the paper's overhead
    /// claim excludes traffic that baseline Spark already sends).
    pub fn peer_protocol_total(&self) -> u64 {
        self.eviction_reports + self.broadcast_deliveries
    }

    pub fn merge(&mut self, other: &MessageStats) {
        self.eviction_reports += other.eviction_reports;
        self.invalidation_broadcasts += other.invalidation_broadcasts;
        self.broadcast_deliveries += other.broadcast_deliveries;
        self.refcount_updates += other.refcount_updates;
        self.profile_broadcasts += other.profile_broadcasts;
    }
}

/// Failure-injection and lineage-recovery accounting (one engine run).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Workers killed by the failure plan.
    pub workers_killed: u64,
    /// Workers revived by the failure plan.
    pub workers_restarted: u64,
    /// Memory-cached blocks lost with killed workers.
    pub blocks_lost_cached: u64,
    /// Spill-area blocks lost with killed workers (a worker kill wipes
    /// its local spill area; recovery re-plans the needed ones exactly
    /// like other lost transform blocks).
    pub blocks_lost_spilled: u64,
    /// Materialized transform blocks whose durable copy died (executor-
    /// local spill; ingest blocks reload from external storage instead).
    pub blocks_lost_durable: u64,
    /// Lineage recompute tasks synthesized (the minimal ancestor closure).
    pub recompute_tasks: u64,
    /// Bytes re-materialized by those tasks.
    pub recompute_bytes: u64,
    /// Modeled time from a kill taking effect until its last recompute
    /// task completed, summed over kills (0 when nothing needed
    /// recomputing).
    pub recovery_nanos: u64,
}

impl RecoveryStats {
    pub fn recovery_time(&self) -> Duration {
        Duration::from_nanos(self.recovery_nanos)
    }
}

/// Elastic-topology accounting for one engine run (DESIGN.md §9):
/// worker joins/retires and the group-atomic warm-up migration they
/// triggered. All zero on fixed-fleet runs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScaleStats {
    /// Workers brought online by `Join` events or autoscale decisions.
    pub workers_joined: u64,
    /// Workers retired by autoscale scale-down decisions (retires reuse
    /// the kill path, so their block loss lands in [`RecoveryStats`]).
    pub workers_retired: u64,
    /// Blocks warm-migrated to a joining worker (memory + spill tiers).
    pub blocks_migrated: u64,
    /// Peer groups moved whole — every migrated member in one pinned
    /// all-or-nothing batch (the group-atomicity invariant).
    pub groups_migrated: u64,
    /// Payload bytes those migrations carried.
    pub migration_bytes: u64,
}

impl ScaleStats {
    pub fn merge(&mut self, other: &ScaleStats) {
        self.workers_joined += other.workers_joined;
        self.workers_retired += other.workers_retired;
        self.blocks_migrated += other.blocks_migrated;
        self.groups_migrated += other.groups_migrated;
        self.migration_bytes += other.migration_bytes;
    }
}

/// Spill-tier accounting for one engine run (DESIGN.md §5): demotions,
/// restores, and what the tier did for task reads — **restored hits**
/// (memory hits that exist only because a group restore promoted the
/// block back; a subset of [`AccessStats::mem_hits`], reported
/// separately here), **spill reads** (served in place from a spill
/// area), and **recomputes** (the bytes left both tiers and lineage
/// re-planned them). All-zero whenever `EngineConfig::spill` is unset.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Blocks demoted into the spill tier.
    pub spilled_blocks: u64,
    pub spilled_bytes: u64,
    /// Coordinated demotion sets admitted whole (all-or-nothing).
    pub groups_demoted: u64,
    /// Memory victims whose demotion was refused (bytes dropped).
    pub demotions_refused: u64,
    /// Spill residents reclaimed for budget room (bytes dropped).
    pub spill_evictions: u64,
    /// Blocks promoted back to memory by group restores.
    pub restored_blocks: u64,
    pub restored_bytes: u64,
    /// Pre-dispatch group restores issued (tasks that needed one).
    pub groups_restored: u64,
    /// Task input reads served from memory by a restored resident — a
    /// **subset** of [`AccessStats::mem_hits`] (a restored read is a
    /// memory hit like any other; this counter reports it separately so
    /// the restore machinery's contribution is visible).
    pub restored_hits: u64,
    /// Task input reads served directly from a spill area
    /// (`RestorePolicy::ReadThrough`, or a restore still in flight).
    pub spill_reads: u64,
    /// Reads of a Dropped block served from the durable async-flush copy
    /// (the block's consumer was already dispatched when the drop
    /// landed, so lineage could not re-plan it).
    pub fallback_durable_reads: u64,
    /// Lineage recompute tasks synthesized for Dropped-but-needed blocks.
    pub spill_recompute_tasks: u64,
    /// Decision logs for the sim ≡ threaded equivalence tests: every
    /// spilled / restored block as a [`crate::spill::block_key`] value,
    /// sorted at report time. Empty unless the spill tier is on.
    pub spilled_log: Vec<u64>,
    pub restored_log: Vec<u64>,
}

impl TierStats {
    /// Reads served by the spill tier one way or another.
    pub fn spill_served(&self) -> u64 {
        self.restored_hits + self.spill_reads
    }

    pub fn merge(&mut self, other: &TierStats) {
        self.spilled_blocks += other.spilled_blocks;
        self.spilled_bytes += other.spilled_bytes;
        self.groups_demoted += other.groups_demoted;
        self.demotions_refused += other.demotions_refused;
        self.spill_evictions += other.spill_evictions;
        self.restored_blocks += other.restored_blocks;
        self.restored_bytes += other.restored_bytes;
        self.groups_restored += other.groups_restored;
        self.restored_hits += other.restored_hits;
        self.spill_reads += other.spill_reads;
        self.fallback_durable_reads += other.fallback_durable_reads;
        self.spill_recompute_tasks += other.spill_recompute_tasks;
        self.spilled_log.extend_from_slice(&other.spilled_log);
        self.restored_log.extend_from_slice(&other.restored_log);
    }

    /// Sort the decision logs (call once when assembling the report, so
    /// per-worker merge order cannot leak into comparisons).
    pub fn finalize(&mut self) {
        self.spilled_log.sort_unstable();
        self.restored_log.sort_unstable();
    }
}

/// Contended-network accounting (all zero unless the run used the
/// simulator's fair-share model, `NetModel::FairShare` — DESIGN.md §6).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetStats {
    /// Transfers that crossed the modeled links.
    pub flows: u64,
    /// Total bytes those transfers carried.
    pub bytes: u64,
    /// Total queueing delay: actual minus uncontended transfer time,
    /// summed over flows.
    pub queueing_nanos: u64,
    /// Busiest link's carried bytes over its capacity × makespan.
    pub max_link_utilization: f64,
    /// Mean utilization across every ingress/egress/disk link.
    pub mean_link_utilization: f64,
}

impl NetStats {
    /// Average queueing delay per flow.
    pub fn mean_queueing_delay(&self) -> Duration {
        if self.flows == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.queueing_nanos / self.flows)
        }
    }
}

/// Everything one engine run produces.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: String,
    /// Makespan of the whole run (ingest + compute) in *modeled* time.
    pub makespan: Duration,
    /// Makespan of the compute phase only (job submission → last task).
    /// This is the paper's Fig 5 "experiment runtime": the input files
    /// already exist when the jobs are submitted.
    pub compute_makespan: Duration,
    /// Per-job completion times (submission → last task).
    pub job_times: BTreeMap<u32, Duration>,
    pub access: AccessStats,
    pub messages: MessageStats,
    pub tasks_run: u64,
    pub evictions: u64,
    /// Insert admissions refused by the policy.
    pub rejected_inserts: u64,
    /// Cluster cache capacity used for the run (bytes).
    pub cache_capacity: u64,
    /// Failure/recovery accounting (all zero on fault-free runs).
    pub recovery: RecoveryStats,
    /// Elastic-topology accounting (all zero on fixed-fleet runs — see
    /// DESIGN.md §9).
    pub scale: ScaleStats,
    /// Spill-tier accounting (all zero unless `EngineConfig::spill` is
    /// set — see DESIGN.md §5).
    pub tier: TierStats,
    /// Contended-network accounting (all zero unless the simulator ran
    /// with `NetModel::FairShare` — see DESIGN.md §6).
    pub net: NetStats,
    /// Ineffective-hit attribution (DESIGN.md §8): which blocking block
    /// broke each peer group and why. Always populated — attribution is
    /// a metric, not a trace, so `TraceConfig::Off` runs report it too.
    pub attribution: AttributionStats,
    /// Continuous telemetry samples (DESIGN.md §10). Empty unless
    /// `EngineConfig::timeline` was set — independent of `TraceConfig`,
    /// so Off-vs-Collect reports stay byte-identical.
    pub timeline: Timeline,
}

impl RunReport {
    pub fn hit_ratio(&self) -> f64 {
        self.access.hit_ratio()
    }

    pub fn effective_hit_ratio(&self) -> f64 {
        self.access.effective_hit_ratio()
    }

    /// Memory hits that bought nothing (the paper's waste metric): the
    /// recovery bench compares policies on this after a mid-job kill.
    pub fn ineffective_hits(&self) -> u64 {
        self.access.mem_hits.saturating_sub(self.access.effective_hits)
    }

    /// JobId-keyed accessor (BTreeMap is u32-keyed for serde friendliness).
    pub fn job_time(&self, job: JobId) -> Option<Duration> {
        self.job_times.get(&job.0).copied()
    }
}

/// Per-job slice of an online multi-job run (one entry per submitted
/// `JobDag`).
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    pub job: u32,
    /// Dispatch priority the job ran at.
    pub priority: u8,
    /// Requested arrival (global dispatch index).
    pub arrival: u64,
    /// Dispatch index at which the job was actually admitted (equals
    /// `arrival` unless the queue quiesced earlier and pulled it in).
    pub admitted_at_dispatch: u64,
    /// Tasks dispatched for this job, including recovery recomputes.
    pub tasks_run: u64,
    /// Lineage recompute tasks synthesized for this job after kills.
    pub recompute_tasks: u64,
    /// Block accesses by this job's tasks only.
    pub access: AccessStats,
    /// Job completion time: admission → last task (modeled time).
    pub jct: Duration,
    /// Dispatch → publish latency per task of this job (DESIGN.md §8).
    pub task_latency: LatencyHistogram,
    /// Ready → dispatch wait per task of this job.
    pub queue_wait: LatencyHistogram,
}

impl JobStats {
    pub fn hit_ratio(&self) -> f64 {
        self.access.hit_ratio()
    }

    pub fn effective_hit_ratio(&self) -> f64 {
        self.access.effective_hit_ratio()
    }
}

/// Everything an online multi-job run produces: the cluster-wide
/// aggregate (identical shape to a single-workload [`RunReport`]) plus
/// one [`JobStats`] per submitted job.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub aggregate: RunReport,
    pub jobs: Vec<JobStats>,
}

impl FleetReport {
    /// Aggregate effective cache hit ratio across every job (Def. 1 over
    /// the whole fleet's accesses).
    pub fn aggregate_effective_hit_ratio(&self) -> f64 {
        self.aggregate.effective_hit_ratio()
    }

    pub fn job(&self, job: JobId) -> Option<&JobStats> {
        self.jobs.iter().find(|j| j.job == job.0)
    }

    pub fn mean_jct(&self) -> Duration {
        if self.jobs.is_empty() {
            return Duration::ZERO;
        }
        let total: Duration = self.jobs.iter().map(|j| j.jct).sum();
        total / self.jobs.len() as u32
    }

    pub fn max_jct(&self) -> Duration {
        self.jobs.iter().map(|j| j.jct).max().unwrap_or(Duration::ZERO)
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_handle_zero_denominator() {
        let s = AccessStats::default();
        assert_eq!(s.hit_ratio(), 0.0);
        assert_eq!(s.effective_hit_ratio(), 0.0);
    }

    #[test]
    fn ratios_compute() {
        let s = AccessStats {
            accesses: 10,
            mem_hits: 6,
            effective_hits: 4,
            ..Default::default()
        };
        assert!((s.hit_ratio() - 0.6).abs() < 1e-12);
        assert!((s.effective_hit_ratio() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = AccessStats {
            accesses: 5,
            mem_hits: 3,
            ..Default::default()
        };
        let b = AccessStats {
            accesses: 7,
            mem_hits: 2,
            effective_hits: 1,
            disk_reads: 4,
            disk_bytes: 100,
            remote_hits: 1,
        };
        a.merge(&b);
        assert_eq!(a.accesses, 12);
        assert_eq!(a.mem_hits, 5);
        assert_eq!(a.effective_hits, 1);
        assert_eq!(a.disk_bytes, 100);
    }

    #[test]
    fn tier_stats_merge_and_finalize() {
        let mut a = TierStats {
            spilled_blocks: 2,
            spilled_bytes: 64,
            restored_hits: 1,
            spilled_log: vec![9, 3],
            ..Default::default()
        };
        let b = TierStats {
            spilled_blocks: 1,
            spill_reads: 4,
            spilled_log: vec![5],
            restored_log: vec![7],
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.spilled_blocks, 3);
        assert_eq!(a.spill_served(), 5);
        a.finalize();
        assert_eq!(a.spilled_log, vec![3, 5, 9]);
        assert_eq!(a.restored_log, vec![7]);
        assert_eq!(TierStats::default(), TierStats::default());
    }

    #[test]
    fn scale_stats_merge_accumulates() {
        let mut a = ScaleStats {
            workers_joined: 1,
            blocks_migrated: 4,
            groups_migrated: 2,
            migration_bytes: 64,
            ..Default::default()
        };
        a.merge(&ScaleStats {
            workers_joined: 1,
            workers_retired: 1,
            blocks_migrated: 3,
            migration_bytes: 32,
            ..Default::default()
        });
        assert_eq!(a.workers_joined, 2);
        assert_eq!(a.workers_retired, 1);
        assert_eq!(a.blocks_migrated, 7);
        assert_eq!(a.groups_migrated, 2);
        assert_eq!(a.migration_bytes, 96);
        assert_eq!(ScaleStats::default(), ScaleStats::default());
    }

    #[test]
    fn peer_protocol_total_excludes_refcounts() {
        let m = MessageStats {
            eviction_reports: 3,
            invalidation_broadcasts: 2,
            broadcast_deliveries: 8,
            refcount_updates: 1000,
            profile_broadcasts: 1,
        };
        assert_eq!(m.peer_protocol_total(), 11);
    }
}
