//! Continuous telemetry timeline (DESIGN.md §10).
//!
//! Both engines sample the same windowed series at the quiescent points
//! they already share — dispatch boundaries, where the global dispatch
//! index is the deterministic clock — so the simulator's timeline is
//! bit-reproducible across repeats and the threaded engine's agrees
//! structurally (same sample schema, same dispatch-index x-axis, wall
//! timestamps instead of logical ones).
//!
//! Counters are *cumulative at sample time*; windowed rates (effective
//! hit ratio over the last window, per-worker busy fraction, link
//! throughput) are derived by differencing adjacent samples, so a
//! sample is cheap to take (reads, no resets) and any prefix of the
//! series is self-consistent. Per-worker busy nanos accrue at op
//! completion, so a sample taken mid-op attributes that op's time to
//! the next window — a one-window smearing, never a loss.
//!
//! The sampler is gated by `EngineConfig::timeline`, deliberately NOT
//! by `TraceConfig`: the flight recorder's Off-vs-Collect byte-identity
//! invariant (tests/trace.rs) compares full reports, and `RunReport`
//! carries the timeline.

use std::collections::BTreeMap;

/// One sample of the continuous telemetry series. All counters are
/// cumulative since run start except the instantaneous gauges
/// (`ready_depth`, `alive_workers`, `mem_*`, `spill_*`, `net_flows`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimelineSample {
    /// Nanos in the run's trace clock domain: the simulator's logical
    /// clock, or wall nanos since run start for the threaded engine
    /// (raw, not divided by `time_scale` — same domain as trace
    /// timestamps, so Perfetto counter tracks line up with spans).
    pub ts: u64,
    /// Global dispatch index at the sample (the shared x-axis).
    pub dispatched: u64,
    /// Ready-queue depth at the sample.
    pub ready_depth: u64,
    /// Alive workers at the sample.
    pub alive_workers: u32,
    /// Memory-tier occupancy across alive workers (blocks / bytes).
    pub mem_blocks: u64,
    pub mem_bytes: u64,
    /// Spill-tier occupancy across alive workers (blocks / bytes).
    pub spill_blocks: u64,
    pub spill_bytes: u64,
    /// Cumulative block accesses / memory hits / effective hits.
    pub accesses: u64,
    pub mem_hits: u64,
    pub effective_hits: u64,
    /// Fair-share network gauges (zero unless the simulator runs
    /// `NetModel::FairShare`): flows in flight, cumulative carried bytes.
    pub net_flows: u64,
    pub net_bytes: u64,
    /// Cumulative modeled busy nanos per worker slot (indexed by worker
    /// id, length = worker ceiling).
    pub worker_busy: Vec<u64>,
}

impl TimelineSample {
    /// Effective-hit ratio of the window ending at this sample, given
    /// the previous sample (or a zeroed one for the first window).
    pub fn window_effective_ratio(&self, prev: &TimelineSample) -> f64 {
        let acc = self.accesses.saturating_sub(prev.accesses);
        if acc == 0 {
            0.0
        } else {
            self.effective_hits.saturating_sub(prev.effective_hits) as f64 / acc as f64
        }
    }

    /// Busy fraction of worker `w` over the window ending at this
    /// sample. Clamped to 1.0 (busy nanos accrue at op completion, so a
    /// long op can land entirely inside one window).
    pub fn window_busy_fraction(&self, prev: &TimelineSample, w: usize) -> f64 {
        let dt = self.ts.saturating_sub(prev.ts);
        if dt == 0 {
            return 0.0;
        }
        let cur = self.worker_busy.get(w).copied().unwrap_or(0);
        let old = prev.worker_busy.get(w).copied().unwrap_or(0);
        (cur.saturating_sub(old) as f64 / dt as f64).min(1.0)
    }
}

/// The sampled series carried on `RunReport::timeline`. Empty (and
/// byte-identical in Debug output) unless `EngineConfig::timeline` was
/// set for the run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Timeline {
    /// Dispatches between samples (`TimelineConfig::every_dispatches`);
    /// 0 when the sampler was off.
    pub every: u64,
    pub samples: Vec<TimelineSample>,
}

impl Timeline {
    pub fn new(every: u64) -> Self {
        Self {
            every,
            samples: Vec::new(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn push(&mut self, s: TimelineSample) {
        self.samples.push(s);
    }

    /// Worker-slot count carried by the widest sample.
    pub fn worker_slots(&self) -> usize {
        self.samples.iter().map(|s| s.worker_busy.len()).max().unwrap_or(0)
    }

    /// Peak ready-queue depth over the run.
    pub fn max_ready_depth(&self) -> u64 {
        self.samples.iter().map(|s| s.ready_depth).max().unwrap_or(0)
    }

    /// Peak memory-tier occupancy (bytes) over the run.
    pub fn max_mem_bytes(&self) -> u64 {
        self.samples.iter().map(|s| s.mem_bytes).max().unwrap_or(0)
    }

    /// Windowed effective-hit ratios, one per sample (first window
    /// starts from zeroed counters).
    pub fn window_effective_ratios(&self) -> Vec<f64> {
        let zero = TimelineSample::default();
        self.samples
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let prev = if i == 0 { &zero } else { &self.samples[i - 1] };
                s.window_effective_ratio(prev)
            })
            .collect()
    }

    /// JSONL export: a `timeline_meta` header, one flat
    /// `timeline_sample` object per sample, and one flat
    /// `timeline_worker` object per (sample, worker) pair — flat so
    /// `trace::summary::parse_flat_json` and `tools/trace_report.py`
    /// can both read it back.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"kind\":\"timeline_meta\",\"schema\":1,\"every\":{},\"samples\":{},\
             \"workers\":{}}}\n",
            self.every,
            self.samples.len(),
            self.worker_slots()
        ));
        for s in &self.samples {
            out.push_str(&format!(
                "{{\"kind\":\"timeline_sample\",\"ts\":{},\"dispatched\":{},\"ready\":{},\
                 \"alive\":{},\"mem_blocks\":{},\"mem_bytes\":{},\"spill_blocks\":{},\
                 \"spill_bytes\":{},\"accesses\":{},\"mem_hits\":{},\"effective_hits\":{},\
                 \"net_flows\":{},\"net_bytes\":{}}}\n",
                s.ts,
                s.dispatched,
                s.ready_depth,
                s.alive_workers,
                s.mem_blocks,
                s.mem_bytes,
                s.spill_blocks,
                s.spill_bytes,
                s.accesses,
                s.mem_hits,
                s.effective_hits,
                s.net_flows,
                s.net_bytes
            ));
            for (w, busy) in s.worker_busy.iter().enumerate() {
                out.push_str(&format!(
                    "{{\"kind\":\"timeline_worker\",\"ts\":{},\"dispatched\":{},\
                     \"worker\":{w},\"busy_nanos\":{busy}}}\n",
                    s.ts, s.dispatched
                ));
            }
        }
        out
    }

    /// Rebuild a timeline from its JSONL export (inverse of
    /// [`Self::to_jsonl`]); unknown kinds and malformed lines are
    /// skipped, mirroring `TraceSummary`'s tolerance.
    pub fn from_jsonl(text: &str) -> Self {
        use crate::trace::summary::parse_flat_json;
        let mut tl = Timeline::default();
        let mut busy_by_ts: BTreeMap<(u64, u64), Vec<(u64, u64)>> = BTreeMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some(obj) = parse_flat_json(line) else { continue };
            let num = |k: &str| obj.get(k).and_then(|v| v.parse::<u64>().ok()).unwrap_or(0);
            match obj.get("kind").map(String::as_str) {
                Some("timeline_meta") => tl.every = num("every"),
                Some("timeline_sample") => tl.samples.push(TimelineSample {
                    ts: num("ts"),
                    dispatched: num("dispatched"),
                    ready_depth: num("ready"),
                    alive_workers: num("alive") as u32,
                    mem_blocks: num("mem_blocks"),
                    mem_bytes: num("mem_bytes"),
                    spill_blocks: num("spill_blocks"),
                    spill_bytes: num("spill_bytes"),
                    accesses: num("accesses"),
                    mem_hits: num("mem_hits"),
                    effective_hits: num("effective_hits"),
                    net_flows: num("net_flows"),
                    net_bytes: num("net_bytes"),
                    worker_busy: Vec::new(),
                }),
                Some("timeline_worker") => busy_by_ts
                    .entry((num("ts"), num("dispatched")))
                    .or_default()
                    .push((num("worker"), num("busy_nanos"))),
                _ => {}
            }
        }
        for s in &mut tl.samples {
            if let Some(mut per_worker) = busy_by_ts.remove(&(s.ts, s.dispatched)) {
                per_worker.sort_unstable();
                let slots = per_worker.iter().map(|&(w, _)| w + 1).max().unwrap_or(0);
                s.worker_busy = vec![0; slots as usize];
                for (w, busy) in per_worker {
                    s.worker_busy[w as usize] = busy;
                }
            }
        }
        tl
    }

    /// Compact human-readable summary (the `lerc analyze` footer).
    pub fn render(&self) -> String {
        use crate::metrics::hist::fmt_nanos;
        if self.is_empty() {
            return String::from("timeline: no samples (sampler off)\n");
        }
        let last = self.samples.last().expect("non-empty");
        let ratios = self.window_effective_ratios();
        let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
        let mut out = String::new();
        out.push_str(&format!(
            "timeline: {} samples (every {} dispatches, span {})\n",
            self.len(),
            self.every,
            fmt_nanos(last.ts.saturating_sub(self.samples[0].ts))
        ));
        out.push_str(&format!(
            "  peak ready depth {}  peak mem {} B  mean windowed eff-hit {mean_ratio:.3}\n",
            self.max_ready_depth(),
            self.max_mem_bytes()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(ts: u64, dispatched: u64, accesses: u64, eff: u64, busy: Vec<u64>) -> TimelineSample {
        TimelineSample {
            ts,
            dispatched,
            ready_depth: 3,
            alive_workers: busy.len() as u32,
            mem_blocks: 5,
            mem_bytes: 5 * 4096,
            accesses,
            mem_hits: accesses,
            effective_hits: eff,
            worker_busy: busy,
            ..Default::default()
        }
    }

    #[test]
    fn windowed_ratios_difference_adjacent_samples() {
        let mut tl = Timeline::new(8);
        tl.push(sample(1_000, 8, 10, 5, vec![500, 0]));
        tl.push(sample(2_000, 16, 30, 25, vec![1_400, 200]));
        let r = tl.window_effective_ratios();
        assert_eq!(r.len(), 2);
        assert!((r[0] - 0.5).abs() < 1e-9);
        // Window 2: (25-5)/(30-10) = 1.0
        assert!((r[1] - 1.0).abs() < 1e-9);
        // Busy fraction of worker 0 over window 2: 900ns / 1000ns.
        let f = tl.samples[1].window_busy_fraction(&tl.samples[0], 0);
        assert!((f - 0.9).abs() < 1e-9);
    }

    #[test]
    fn jsonl_round_trips() {
        let mut tl = Timeline::new(4);
        tl.push(sample(100, 4, 8, 8, vec![50, 60]));
        tl.push(sample(200, 8, 16, 12, vec![150, 160]));
        let text = tl.to_jsonl();
        assert!(text.starts_with("{\"kind\":\"timeline_meta\""));
        let back = Timeline::from_jsonl(&text);
        assert_eq!(back, tl);
    }

    #[test]
    fn empty_timeline_renders_and_exports() {
        let tl = Timeline::default();
        assert!(tl.is_empty());
        assert!(tl.render().contains("no samples"));
        assert!(tl.to_jsonl().contains("\"samples\":0"));
    }
}
