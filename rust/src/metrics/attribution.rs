//! Ineffective-hit attribution (DESIGN.md §8).
//!
//! Def. 1 makes a hit *effective* only when the task's entire peer group
//! is served from memory (local or remote — remote memory keeps a group
//! whole; see `sim::engine` and `driver::worker`). The aggregate
//! counters say how many hits were ineffective; attribution says *which
//! co-member block* broke each group and *why*. The rule, shared
//! verbatim by both engines through [`attribute_group`]:
//!
//! * a group where every member was memory-served attributes nothing;
//! * otherwise every access in the group is attributed exactly once —
//!   memory-served members blame the first (lowest input index)
//!   non-memory co-member, non-memory members blame themselves — so the
//!   attributed total reconciles exactly with
//!   `accesses - effective_hits`.
//!
//! The blocking block's cause is ranked: a block with a recompute task
//! planned is `recomputing`; a block read through the spill tier is
//! `spilled-not-restored`; a miss served from a remote home's durable
//! copy is `remote`; everything else (the bytes were simply gone from
//! memory) is `evicted`.

use crate::common::ids::BlockId;
use std::collections::BTreeMap;
use std::fmt;

/// Which tier actually served one input read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServedFrom {
    /// The reader's own memory store.
    LocalMem,
    /// A remote home's memory store (effective-eligible, like local).
    RemoteMem,
    /// Read-through from a home's spill area (disk-priced).
    Spilled,
    /// Durable copy, home co-located with the reader.
    LocalDisk,
    /// Durable copy, home on another worker.
    RemoteDisk,
}

impl ServedFrom {
    /// Memory-served reads keep a peer group effective (Def. 1).
    pub fn memory(self) -> bool {
        matches!(self, ServedFrom::LocalMem | ServedFrom::RemoteMem)
    }
}

/// Why a blocking co-member was not in memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IneffectiveCause {
    Evicted,
    SpilledNotRestored,
    Remote,
    Recomputing,
}

impl IneffectiveCause {
    pub fn as_str(self) -> &'static str {
        match self {
            IneffectiveCause::Evicted => "evicted",
            IneffectiveCause::SpilledNotRestored => "spilled-not-restored",
            IneffectiveCause::Remote => "remote",
            IneffectiveCause::Recomputing => "recomputing",
        }
    }
}

impl fmt::Display for IneffectiveCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Classify a non-memory serve into its blocking cause.
pub fn classify(sf: ServedFrom, recompute_planned: bool) -> IneffectiveCause {
    if recompute_planned {
        return IneffectiveCause::Recomputing;
    }
    match sf {
        ServedFrom::Spilled => IneffectiveCause::SpilledNotRestored,
        ServedFrom::RemoteDisk => IneffectiveCause::Remote,
        // LocalDisk; the memory variants never block a group.
        _ => IneffectiveCause::Evicted,
    }
}

/// Aggregated ineffective-hit attribution, on every `RunReport`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AttributionStats {
    /// Ineffective accesses blocked by a plainly-evicted co-member.
    pub evicted: u64,
    /// Blocked by a co-member demoted to the spill tier and not restored
    /// before the read.
    pub spilled_not_restored: u64,
    /// Blocked by a miss served from a remote home's durable copy.
    pub remote: u64,
    /// Blocked by a co-member whose recompute was planned but had not
    /// re-materialized yet.
    pub recomputing: u64,
    /// Per-blocking-block attributed-access counts (deterministic order
    /// for reports and the Off-is-byte-identical invariant).
    pub blocking: BTreeMap<BlockId, u64>,
}

impl AttributionStats {
    /// Record one attributed access.
    pub fn record(&mut self, cause: IneffectiveCause, blocking: BlockId) {
        match cause {
            IneffectiveCause::Evicted => self.evicted += 1,
            IneffectiveCause::SpilledNotRestored => self.spilled_not_restored += 1,
            IneffectiveCause::Remote => self.remote += 1,
            IneffectiveCause::Recomputing => self.recomputing += 1,
        }
        *self.blocking.entry(blocking).or_default() += 1;
    }

    pub fn merge(&mut self, other: &Self) {
        self.evicted += other.evicted;
        self.spilled_not_restored += other.spilled_not_restored;
        self.remote += other.remote;
        self.recomputing += other.recomputing;
        for (b, n) in &other.blocking {
            *self.blocking.entry(*b).or_default() += n;
        }
    }

    /// Total attributed accesses; equals `accesses - effective_hits`
    /// when every read flowed through the attribution path.
    pub fn total(&self) -> u64 {
        self.evicted + self.spilled_not_restored + self.remote + self.recomputing
    }

    /// Top-K blocking blocks by attributed-access count (count
    /// descending, block id ascending on ties).
    pub fn top_blocking(&self, k: usize) -> Vec<(BlockId, u64)> {
        let mut v: Vec<(BlockId, u64)> = self.blocking.iter().map(|(b, n)| (*b, *n)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// `(cause, count)` pairs in a fixed order for tables.
    pub fn by_cause(&self) -> [(IneffectiveCause, u64); 4] {
        [
            (IneffectiveCause::Evicted, self.evicted),
            (IneffectiveCause::SpilledNotRestored, self.spilled_not_restored),
            (IneffectiveCause::Remote, self.remote),
            (IneffectiveCause::Recomputing, self.recomputing),
        ]
    }
}

/// Attribute one task's input reads. No-op when the group is whole
/// (every member memory-served); otherwise records every access into
/// `stats` and calls `emit(accessed_member, blocking_block, cause)` per
/// attributed access (the engines forward these to the flight recorder).
pub fn attribute_group<R, E>(
    served: &[(BlockId, ServedFrom)],
    recompute_planned: R,
    stats: &mut AttributionStats,
    mut emit: E,
) where
    R: Fn(BlockId) -> bool,
    E: FnMut(BlockId, BlockId, IneffectiveCause),
{
    let first_blocker = served.iter().find(|(_, s)| !s.memory());
    let Some(&(first_block, first_sf)) = first_blocker else {
        return; // group is whole: nothing to attribute
    };
    let first_cause = classify(first_sf, recompute_planned(first_block));
    for &(member, sf) in served {
        let (blocking, cause) = if sf.memory() {
            (first_block, first_cause)
        } else {
            (member, classify(sf, recompute_planned(member)))
        };
        stats.record(cause, blocking);
        emit(member, blocking, cause);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{BlockId, DatasetId};

    fn b(d: u32, i: u32) -> BlockId {
        BlockId::new(DatasetId(d), i)
    }

    #[test]
    fn whole_group_attributes_nothing() {
        let served = [(b(0, 0), ServedFrom::LocalMem), (b(1, 0), ServedFrom::RemoteMem)];
        let mut stats = AttributionStats::default();
        attribute_group(&served, |_| false, &mut stats, |_, _, _| panic!("no emits"));
        assert_eq!(stats.total(), 0);
    }

    #[test]
    fn broken_group_attributes_every_access_once() {
        // mem, disk(local), mem: 3 accesses, all attributed to the one
        // evicted blocker.
        let served = [
            (b(0, 0), ServedFrom::LocalMem),
            (b(1, 0), ServedFrom::LocalDisk),
            (b(2, 0), ServedFrom::RemoteMem),
        ];
        let mut stats = AttributionStats::default();
        let mut emitted = Vec::new();
        attribute_group(&served, |_| false, &mut stats, |m, blk, c| emitted.push((m, blk, c)));
        assert_eq!(stats.total(), 3);
        assert_eq!(stats.evicted, 3);
        assert_eq!(stats.blocking.get(&b(1, 0)), Some(&3));
        assert_eq!(emitted.len(), 3);
        assert!(emitted.iter().all(|&(_, blk, _)| blk == b(1, 0)));
    }

    #[test]
    fn non_memory_members_blame_themselves() {
        let served = [
            (b(0, 0), ServedFrom::Spilled),
            (b(1, 0), ServedFrom::RemoteDisk),
        ];
        let mut stats = AttributionStats::default();
        attribute_group(&served, |_| false, &mut stats, |_, _, _| {});
        assert_eq!(stats.spilled_not_restored, 1);
        assert_eq!(stats.remote, 1);
        assert_eq!(stats.blocking.get(&b(0, 0)), Some(&1));
        assert_eq!(stats.blocking.get(&b(1, 0)), Some(&1));
    }

    #[test]
    fn recompute_planned_outranks_tier() {
        let served = [
            (b(0, 0), ServedFrom::LocalMem),
            (b(1, 0), ServedFrom::RemoteDisk),
        ];
        let mut stats = AttributionStats::default();
        attribute_group(&served, |blk| blk == b(1, 0), &mut stats, |_, _, _| {});
        assert_eq!(stats.recomputing, 2);
        assert_eq!(stats.remote, 0);
    }

    #[test]
    fn top_blocking_orders_by_count_then_id() {
        let mut stats = AttributionStats::default();
        for _ in 0..3 {
            stats.record(IneffectiveCause::Evicted, b(1, 1));
        }
        stats.record(IneffectiveCause::Evicted, b(0, 0));
        stats.record(IneffectiveCause::Evicted, b(2, 2));
        let top = stats.top_blocking(2);
        assert_eq!(top, vec![(b(1, 1), 3), (b(0, 0), 1)]);
    }

    #[test]
    fn merge_sums_causes_and_blocking() {
        let mut a = AttributionStats::default();
        let mut c = AttributionStats::default();
        a.record(IneffectiveCause::Evicted, b(0, 0));
        c.record(IneffectiveCause::Remote, b(0, 0));
        a.merge(&c);
        assert_eq!(a.total(), 2);
        assert_eq!(a.blocking.get(&b(0, 0)), Some(&2));
    }
}
