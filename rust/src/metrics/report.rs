//! Report formatting: markdown and CSV emitters for the harness.

use crate::metrics::hist::{fmt_nanos, LatencyHistogram};
use crate::metrics::{FleetReport, RunReport};
use std::fmt::Write as _;

/// One row per (cache size, policy) — the shape of the paper's Fig 5–7.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub cache_bytes: u64,
    pub cache_fraction: f64,
    pub policy: String,
    pub makespan_s: f64,
    pub hit_ratio: f64,
    pub effective_hit_ratio: f64,
    pub peer_messages: u64,
}

impl SweepRow {
    pub fn from_report(r: &RunReport, input_bytes: u64) -> Self {
        Self {
            cache_bytes: r.cache_capacity,
            cache_fraction: if input_bytes == 0 {
                0.0
            } else {
                r.cache_capacity as f64 / input_bytes as f64
            },
            policy: r.policy.clone(),
            makespan_s: r.compute_makespan.as_secs_f64(),
            hit_ratio: r.hit_ratio(),
            effective_hit_ratio: r.effective_hit_ratio(),
            peer_messages: r.messages.peer_protocol_total(),
        }
    }
}

/// Render sweep rows as a markdown table (the harness's stdout format).
pub fn markdown_table(rows: &[SweepRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "| cache (MiB) | fraction | policy | makespan (s) | hit ratio | effective hit ratio | peer msgs |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|\n");
    for r in rows {
        let _ = writeln!(
            out,
            "| {:.1} | {:.2} | {} | {:.3} | {:.3} | {:.3} | {} |",
            r.cache_bytes as f64 / (1024.0 * 1024.0),
            r.cache_fraction,
            r.policy,
            r.makespan_s,
            r.hit_ratio,
            r.effective_hit_ratio,
            r.peer_messages
        );
    }
    out
}

/// Render a multi-job run's per-job breakdown as a markdown table (the
/// multijob bench's and demo's stdout format).
pub fn fleet_table(fleet: &FleetReport) -> String {
    let mut out = String::new();
    out.push_str(
        "| job | prio | arrival | admitted | tasks | JCT (s) | hit ratio | eff ratio | task p50 | task p99 | wait p99 |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
    for j in &fleet.jobs {
        let _ = writeln!(
            out,
            "| J{} | {} | {} | {} | {} | {:.3} | {:.3} | {:.3} | {} | {} | {} |",
            j.job,
            j.priority,
            j.arrival,
            j.admitted_at_dispatch,
            j.tasks_run,
            j.jct.as_secs_f64(),
            j.hit_ratio(),
            j.effective_hit_ratio(),
            fmt_nanos(j.task_latency.p50()),
            fmt_nanos(j.task_latency.p99()),
            fmt_nanos(j.queue_wait.p99())
        );
    }
    let mut all_lat = LatencyHistogram::new();
    let mut all_wait = LatencyHistogram::new();
    for j in &fleet.jobs {
        all_lat.merge(&j.task_latency);
        all_wait.merge(&j.queue_wait);
    }
    let _ = writeln!(
        out,
        "| all | — | — | — | {} | max {:.3} | {:.3} | {:.3} | {} | {} | {} |",
        fleet.aggregate.tasks_run,
        fleet.max_jct().as_secs_f64(),
        fleet.aggregate.hit_ratio(),
        fleet.aggregate_effective_hit_ratio(),
        fmt_nanos(all_lat.p50()),
        fmt_nanos(all_lat.p99()),
        fmt_nanos(all_wait.p99())
    );
    // Trailer lines for the optional subsystems, only when they ran:
    // elastic topology, the contended network model, and the telemetry
    // sampler. Fixed-fleet flat-net default runs keep the 5-line table.
    let agg = &fleet.aggregate;
    if agg.scale.workers_joined > 0 || agg.scale.workers_retired > 0 {
        let _ = writeln!(
            out,
            "scale: {} joined, {} retired, {} groups migrated ({} blocks, {} B)",
            agg.scale.workers_joined,
            agg.scale.workers_retired,
            agg.scale.groups_migrated,
            agg.scale.blocks_migrated,
            agg.scale.migration_bytes
        );
    }
    if agg.net.flows > 0 {
        let _ = writeln!(
            out,
            "net: {} flows, {} B carried, mean queueing {}, link util mean {:.3} max {:.3}",
            agg.net.flows,
            agg.net.bytes,
            fmt_nanos(agg.net.mean_queueing_delay().as_nanos() as u64),
            agg.net.mean_link_utilization,
            agg.net.max_link_utilization
        );
    }
    if !agg.timeline.is_empty() {
        out.push_str(&agg.timeline.render());
    }
    out
}

/// Render a run's ineffective-hit attribution: counts by cause plus the
/// top-K blocking blocks (DESIGN.md §8).
pub fn attribution_table(r: &RunReport, top_k: usize) -> String {
    let a = &r.attribution;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "ineffective accesses attributed: {} (of {} accesses, {} effective hits)",
        a.total(),
        r.access.accesses,
        r.access.effective_hits
    );
    for (cause, n) in a.by_cause() {
        let _ = writeln!(out, "  {:<22} {}", cause.as_str(), n);
    }
    let top = a.top_blocking(top_k);
    if !top.is_empty() {
        let _ = writeln!(out, "top blocking blocks:");
        for (b, n) in top {
            let _ = writeln!(out, "  {:<22} {}", b.to_string(), n);
        }
    }
    out
}

/// Render sweep rows as CSV (for plotting).
pub fn csv(rows: &[SweepRow]) -> String {
    let mut out =
        String::from("cache_bytes,cache_fraction,policy,makespan_s,hit_ratio,effective_hit_ratio,peer_messages\n");
    for r in rows {
        let _ = writeln!(
            out,
            "{},{:.4},{},{:.6},{:.6},{:.6},{}",
            r.cache_bytes,
            r.cache_fraction,
            r.policy,
            r.makespan_s,
            r.hit_ratio,
            r.effective_hit_ratio,
            r.peer_messages
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{AccessStats, MessageStats};
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn report() -> RunReport {
        RunReport {
            policy: "LERC".into(),
            makespan: Duration::from_secs_f64(1.5),
            compute_makespan: Duration::from_secs_f64(1.5),
            job_times: BTreeMap::new(),
            access: AccessStats {
                accesses: 10,
                mem_hits: 5,
                effective_hits: 4,
                ..Default::default()
            },
            messages: MessageStats {
                eviction_reports: 2,
                broadcast_deliveries: 8,
                ..Default::default()
            },
            tasks_run: 7,
            evictions: 3,
            rejected_inserts: 1,
            cache_capacity: 4 * 1024 * 1024,
            recovery: Default::default(),
            scale: Default::default(),
            tier: Default::default(),
            net: Default::default(),
            attribution: Default::default(),
            timeline: Default::default(),
        }
    }

    #[test]
    fn sweep_row_extracts_fields() {
        let row = SweepRow::from_report(&report(), 8 * 1024 * 1024);
        assert_eq!(row.policy, "LERC");
        assert!((row.cache_fraction - 0.5).abs() < 1e-12);
        assert!((row.hit_ratio - 0.5).abs() < 1e-12);
        assert_eq!(row.peer_messages, 10);
    }

    #[test]
    fn fleet_table_lists_jobs_and_aggregate() {
        use crate::metrics::{FleetReport, JobStats};
        let fleet = FleetReport {
            aggregate: report(),
            jobs: vec![
                JobStats {
                    job: 0,
                    tasks_run: 4,
                    jct: Duration::from_secs_f64(0.5),
                    ..Default::default()
                },
                JobStats {
                    job: 1,
                    priority: 2,
                    arrival: 4,
                    admitted_at_dispatch: 4,
                    tasks_run: 3,
                    jct: Duration::from_secs_f64(1.0),
                    ..Default::default()
                },
            ],
        };
        let md = fleet_table(&fleet);
        assert!(md.contains("J0"));
        assert!(md.contains("J1"));
        assert_eq!(md.lines().count(), 5, "{md}");
        assert!((fleet.mean_jct().as_secs_f64() - 0.75).abs() < 1e-9);
        assert!((fleet.max_jct().as_secs_f64() - 1.0).abs() < 1e-9);
        assert_eq!(fleet.job(crate::common::ids::JobId(1)).unwrap().priority, 2);
    }

    #[test]
    fn fleet_table_renders_scale_net_and_timeline_trailers() {
        use crate::metrics::{
            FleetReport, JobStats, NetStats, ScaleStats, Timeline, TimelineSample,
        };
        let mut agg = report();
        agg.scale = ScaleStats {
            workers_joined: 2,
            workers_retired: 1,
            blocks_migrated: 12,
            groups_migrated: 4,
            migration_bytes: 12 * 4096,
        };
        agg.net = NetStats {
            flows: 9,
            bytes: 9 * 4096,
            queueing_nanos: 9_000,
            max_link_utilization: 0.75,
            mean_link_utilization: 0.25,
        };
        let mut tl = Timeline::new(8);
        tl.push(TimelineSample {
            ts: 1_000,
            dispatched: 8,
            ready_depth: 3,
            accesses: 10,
            effective_hits: 5,
            mem_bytes: 4096,
            worker_busy: vec![500, 400],
            ..Default::default()
        });
        agg.timeline = tl;
        let fleet = FleetReport {
            aggregate: agg,
            jobs: vec![JobStats {
                job: 0,
                tasks_run: 7,
                jct: Duration::from_secs_f64(1.5),
                ..Default::default()
            }],
        };
        let md = fleet_table(&fleet);
        // Golden-ish: required columns/fields present, layout free.
        assert!(md.contains("scale: 2 joined, 1 retired, 4 groups migrated"), "{md}");
        assert!(md.contains("net: 9 flows"), "{md}");
        assert!(md.contains("link util mean 0.250 max 0.750"), "{md}");
        assert!(md.contains("timeline: 1 samples (every 8 dispatches"), "{md}");
        assert!(md.contains("peak ready depth 3"), "{md}");
        // Default-subsystem reports still render the bare 5-line table.
        let bare = FleetReport {
            aggregate: report(),
            jobs: vec![JobStats::default()],
        };
        assert_eq!(fleet_table(&bare).lines().count(), 4);
    }

    #[test]
    fn attribution_table_lists_causes_and_blockers() {
        use crate::common::ids::{BlockId, DatasetId};
        use crate::metrics::attribution::IneffectiveCause;
        let mut r = report();
        r.attribution
            .record(IneffectiveCause::Evicted, BlockId::new(DatasetId(1), 3));
        let out = attribution_table(&r, 5);
        assert!(out.contains("evicted"));
        assert!(out.contains("D1[3]"));
        assert!(out.contains("attributed: 1"));
    }

    #[test]
    fn markdown_and_csv_contain_rows() {
        let rows = vec![SweepRow::from_report(&report(), 8 * 1024 * 1024)];
        let md = markdown_table(&rows);
        assert!(md.contains("LERC"));
        assert!(md.lines().count() == 3);
        let c = csv(&rows);
        assert!(c.starts_with("cache_bytes"));
        assert!(c.contains("LERC"));
    }
}
