//! Report formatting: markdown and CSV emitters for the harness.

use crate::metrics::hist::{fmt_nanos, LatencyHistogram};
use crate::metrics::{FleetReport, RunReport};
use std::fmt::Write as _;

/// One row per (cache size, policy) — the shape of the paper's Fig 5–7.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub cache_bytes: u64,
    pub cache_fraction: f64,
    pub policy: String,
    pub makespan_s: f64,
    pub hit_ratio: f64,
    pub effective_hit_ratio: f64,
    pub peer_messages: u64,
}

impl SweepRow {
    pub fn from_report(r: &RunReport, input_bytes: u64) -> Self {
        Self {
            cache_bytes: r.cache_capacity,
            cache_fraction: if input_bytes == 0 {
                0.0
            } else {
                r.cache_capacity as f64 / input_bytes as f64
            },
            policy: r.policy.clone(),
            makespan_s: r.compute_makespan.as_secs_f64(),
            hit_ratio: r.hit_ratio(),
            effective_hit_ratio: r.effective_hit_ratio(),
            peer_messages: r.messages.peer_protocol_total(),
        }
    }
}

/// Render sweep rows as a markdown table (the harness's stdout format).
pub fn markdown_table(rows: &[SweepRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "| cache (MiB) | fraction | policy | makespan (s) | hit ratio | effective hit ratio | peer msgs |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|\n");
    for r in rows {
        let _ = writeln!(
            out,
            "| {:.1} | {:.2} | {} | {:.3} | {:.3} | {:.3} | {} |",
            r.cache_bytes as f64 / (1024.0 * 1024.0),
            r.cache_fraction,
            r.policy,
            r.makespan_s,
            r.hit_ratio,
            r.effective_hit_ratio,
            r.peer_messages
        );
    }
    out
}

/// Render a multi-job run's per-job breakdown as a markdown table (the
/// multijob bench's and demo's stdout format).
pub fn fleet_table(fleet: &FleetReport) -> String {
    let mut out = String::new();
    out.push_str(
        "| job | prio | arrival | admitted | tasks | JCT (s) | hit ratio | eff ratio | task p50 | task p99 | wait p99 |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
    for j in &fleet.jobs {
        let _ = writeln!(
            out,
            "| J{} | {} | {} | {} | {} | {:.3} | {:.3} | {:.3} | {} | {} | {} |",
            j.job,
            j.priority,
            j.arrival,
            j.admitted_at_dispatch,
            j.tasks_run,
            j.jct.as_secs_f64(),
            j.hit_ratio(),
            j.effective_hit_ratio(),
            fmt_nanos(j.task_latency.p50()),
            fmt_nanos(j.task_latency.p99()),
            fmt_nanos(j.queue_wait.p99())
        );
    }
    let mut all_lat = LatencyHistogram::new();
    let mut all_wait = LatencyHistogram::new();
    for j in &fleet.jobs {
        all_lat.merge(&j.task_latency);
        all_wait.merge(&j.queue_wait);
    }
    let _ = writeln!(
        out,
        "| all | — | — | — | {} | max {:.3} | {:.3} | {:.3} | {} | {} | {} |",
        fleet.aggregate.tasks_run,
        fleet.max_jct().as_secs_f64(),
        fleet.aggregate.hit_ratio(),
        fleet.aggregate_effective_hit_ratio(),
        fmt_nanos(all_lat.p50()),
        fmt_nanos(all_lat.p99()),
        fmt_nanos(all_wait.p99())
    );
    out
}

/// Render a run's ineffective-hit attribution: counts by cause plus the
/// top-K blocking blocks (DESIGN.md §8).
pub fn attribution_table(r: &RunReport, top_k: usize) -> String {
    let a = &r.attribution;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "ineffective accesses attributed: {} (of {} accesses, {} effective hits)",
        a.total(),
        r.access.accesses,
        r.access.effective_hits
    );
    for (cause, n) in a.by_cause() {
        let _ = writeln!(out, "  {:<22} {}", cause.as_str(), n);
    }
    let top = a.top_blocking(top_k);
    if !top.is_empty() {
        let _ = writeln!(out, "top blocking blocks:");
        for (b, n) in top {
            let _ = writeln!(out, "  {:<22} {}", b.to_string(), n);
        }
    }
    out
}

/// Render sweep rows as CSV (for plotting).
pub fn csv(rows: &[SweepRow]) -> String {
    let mut out =
        String::from("cache_bytes,cache_fraction,policy,makespan_s,hit_ratio,effective_hit_ratio,peer_messages\n");
    for r in rows {
        let _ = writeln!(
            out,
            "{},{:.4},{},{:.6},{:.6},{:.6},{}",
            r.cache_bytes,
            r.cache_fraction,
            r.policy,
            r.makespan_s,
            r.hit_ratio,
            r.effective_hit_ratio,
            r.peer_messages
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{AccessStats, MessageStats};
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn report() -> RunReport {
        RunReport {
            policy: "LERC".into(),
            makespan: Duration::from_secs_f64(1.5),
            compute_makespan: Duration::from_secs_f64(1.5),
            job_times: BTreeMap::new(),
            access: AccessStats {
                accesses: 10,
                mem_hits: 5,
                effective_hits: 4,
                ..Default::default()
            },
            messages: MessageStats {
                eviction_reports: 2,
                broadcast_deliveries: 8,
                ..Default::default()
            },
            tasks_run: 7,
            evictions: 3,
            rejected_inserts: 1,
            cache_capacity: 4 * 1024 * 1024,
            recovery: Default::default(),
            scale: Default::default(),
            tier: Default::default(),
            net: Default::default(),
            attribution: Default::default(),
        }
    }

    #[test]
    fn sweep_row_extracts_fields() {
        let row = SweepRow::from_report(&report(), 8 * 1024 * 1024);
        assert_eq!(row.policy, "LERC");
        assert!((row.cache_fraction - 0.5).abs() < 1e-12);
        assert!((row.hit_ratio - 0.5).abs() < 1e-12);
        assert_eq!(row.peer_messages, 10);
    }

    #[test]
    fn fleet_table_lists_jobs_and_aggregate() {
        use crate::metrics::{FleetReport, JobStats};
        let fleet = FleetReport {
            aggregate: report(),
            jobs: vec![
                JobStats {
                    job: 0,
                    tasks_run: 4,
                    jct: Duration::from_secs_f64(0.5),
                    ..Default::default()
                },
                JobStats {
                    job: 1,
                    priority: 2,
                    arrival: 4,
                    admitted_at_dispatch: 4,
                    tasks_run: 3,
                    jct: Duration::from_secs_f64(1.0),
                    ..Default::default()
                },
            ],
        };
        let md = fleet_table(&fleet);
        assert!(md.contains("J0"));
        assert!(md.contains("J1"));
        assert_eq!(md.lines().count(), 5, "{md}");
        assert!((fleet.mean_jct().as_secs_f64() - 0.75).abs() < 1e-9);
        assert!((fleet.max_jct().as_secs_f64() - 1.0).abs() < 1e-9);
        assert_eq!(fleet.job(crate::common::ids::JobId(1)).unwrap().priority, 2);
    }

    #[test]
    fn attribution_table_lists_causes_and_blockers() {
        use crate::common::ids::{BlockId, DatasetId};
        use crate::metrics::attribution::IneffectiveCause;
        let mut r = report();
        r.attribution
            .record(IneffectiveCause::Evicted, BlockId::new(DatasetId(1), 3));
        let out = attribution_table(&r, 5);
        assert!(out.contains("evicted"));
        assert!(out.contains("D1[3]"));
        assert!(out.contains("attributed: 1"));
    }

    #[test]
    fn markdown_and_csv_contain_rows() {
        let rows = vec![SweepRow::from_report(&report(), 8 * 1024 * 1024)];
        let md = markdown_table(&rows);
        assert!(md.contains("LERC"));
        assert!(md.lines().count() == 3);
        let c = csv(&rows);
        assert!(c.starts_with("cache_bytes"));
        assert!(c.contains("LERC"));
    }
}
