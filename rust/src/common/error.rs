//! Engine error type. Library code returns `EngineError`; binaries wrap it
//! in `eyre` for reporting.

use crate::common::ids::{BlockId, TaskId};
use thiserror::Error;

#[derive(Debug, Error)]
pub enum EngineError {
    #[error("block {0} not found in any storage tier")]
    BlockNotFound(BlockId),

    #[error("block {block} exceeds cache capacity ({size} > {capacity} bytes)")]
    BlockTooLarge {
        block: BlockId,
        size: u64,
        capacity: u64,
    },

    #[error("task {0} has unmaterialized input {1}")]
    MissingInput(TaskId, BlockId),

    #[error("artifact for task kind `{0}` block_len {1} not found in manifest")]
    ArtifactMissing(String, usize),

    #[error("xla runtime error: {0}")]
    Xla(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest parse error: {0}")]
    Manifest(String),

    #[error("channel closed: {0}")]
    ChannelClosed(&'static str),

    #[error("configuration error: {0}")]
    Config(String),

    #[error("internal invariant violated: {0}")]
    Invariant(String),
}

pub type Result<T> = std::result::Result<T, EngineError>;
