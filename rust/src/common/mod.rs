//! Shared primitives: typed ids, configuration, errors, deterministic RNG.

pub mod config;
pub mod error;
pub mod fxhash;
pub mod ids;
pub mod rng;
pub mod tempdir;
