//! Engine configuration: cluster shape, cache policy, disk/network models,
//! and the compute backend.
//!
//! The disk model reproduces the paper's testbed characteristics (direct
//! I/O to a 2016-class HDD) as a deterministic throttle: a read of `n`
//! bytes costs `seek_latency + n / bandwidth`. Memory hits cost nothing but
//! the copy. This is the substitution documented in DESIGN.md §2.

use crate::recovery::plan::{FailurePlan, TopologyEvent, TopologyPlan};
use std::path::PathBuf;
use std::time::Duration;

/// Which eviction policy a worker's block manager runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Least-recently-used (Spark default; paper baseline).
    Lru,
    /// Least-frequently-used.
    Lfu,
    /// First-in-first-out.
    Fifo,
    /// LRFU with exponential decay (Lee et al., 2001).
    Lrfu,
    /// LRU-K with K = 2 (O'Neil et al., 1993).
    LruK,
    /// Least Reference Count (Yu et al., INFOCOM 2017) — DAG-aware baseline.
    Lrc,
    /// Least *Effective* Reference Count — the paper's contribution.
    Lerc,
    /// Naive all-or-nothing strawman from §III-A: evict whole peer-groups.
    Sticky,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 8] = [
        PolicyKind::Lru,
        PolicyKind::Lfu,
        PolicyKind::Fifo,
        PolicyKind::Lrfu,
        PolicyKind::LruK,
        PolicyKind::Lrc,
        PolicyKind::Lerc,
        PolicyKind::Sticky,
    ];

    /// The three policies compared in the paper's evaluation (Fig 5–7).
    pub const PAPER: [PolicyKind; 3] = [PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc];

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Lru => "LRU",
            PolicyKind::Lfu => "LFU",
            PolicyKind::Fifo => "FIFO",
            PolicyKind::Lrfu => "LRFU",
            PolicyKind::LruK => "LRU-2",
            PolicyKind::Lrc => "LRC",
            PolicyKind::Lerc => "LERC",
            PolicyKind::Sticky => "Sticky",
        }
    }

    /// Does this policy consume DAG reference counts?
    pub fn dag_aware(&self) -> bool {
        matches!(self, PolicyKind::Lrc | PolicyKind::Lerc | PolicyKind::Sticky)
    }

    /// Does this policy consume peer-group (effective-reference) updates?
    pub fn peer_aware(&self) -> bool {
        matches!(self, PolicyKind::Lerc | PolicyKind::Sticky)
    }
}

/// Disk tier model: real files, deterministic throttle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskConfig {
    /// Sequential bandwidth in bytes/second (default 120 MB/s, HDD-class).
    pub bandwidth_bytes_per_sec: u64,
    /// Per-read seek/setup latency (default 8 ms).
    pub seek_latency: Duration,
    /// If true, skip the throttle sleeps (unit tests / micro benches).
    pub unthrottled: bool,
}

impl Default for DiskConfig {
    fn default() -> Self {
        Self {
            bandwidth_bytes_per_sec: 120 * 1024 * 1024,
            seek_latency: Duration::from_millis(8),
            unthrottled: false,
        }
    }
}

impl DiskConfig {
    /// Cost of reading/writing `bytes` bytes under this model.
    pub fn io_cost(&self, bytes: u64) -> Duration {
        if self.unthrottled {
            return Duration::ZERO;
        }
        let xfer = Duration::from_secs_f64(bytes as f64 / self.bandwidth_bytes_per_sec as f64);
        self.seek_latency + xfer
    }
}

/// Memory-tier read model: a cached block is NOT free to consume — Spark
/// 1.6 memory reads are deserialization-bound (~100 MB/s/core with Java
/// serialization). This is what keeps the paper's memory-vs-disk speedup
/// at ~2–3× rather than ∞ (Fig 5's 37%, not 95%).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemConfig {
    /// Deserialization/copy throughput for memory-served blocks.
    pub bandwidth_bytes_per_sec: u64,
}

impl Default for MemConfig {
    fn default() -> Self {
        Self {
            bandwidth_bytes_per_sec: 100 * 1024 * 1024,
        }
    }
}

impl MemConfig {
    pub fn read_cost(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.bandwidth_bytes_per_sec as f64)
    }
}

/// How demotion victims are chosen for the spill tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpillMode {
    /// LERC-coordinated demotion: a memory victim's *entire remaining
    /// local peer group* demotes together (all-or-nothing, mirroring
    /// `pin_group`), admission refuses blocks no pending task will read
    /// again (spill budget is never spent on dead bytes), and budget
    /// pressure only ever reclaims dead residents — a needed block,
    /// once spilled, stays spilled until restored.
    Coordinated,
    /// Naive per-block demotion (the baseline the spill bench compares
    /// against): every evicted transform block is spilled individually
    /// and budget pressure drops the oldest resident regardless of
    /// whether anything still needs it.
    PerBlock,
}

impl SpillMode {
    pub fn name(&self) -> &'static str {
        match self {
            SpillMode::Coordinated => "coordinated",
            SpillMode::PerBlock => "per_block",
        }
    }
}

/// How spilled blocks are brought back for a dependent task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RestorePolicy {
    /// Pre-dispatch group restore: before a task dispatches, every
    /// spilled member of its input group is promoted back to memory at
    /// its home worker (and pinned until the task retires), so the task
    /// can still count a *restored* all-in-memory hit.
    GroupPromote,
    /// Serve spilled bytes directly from the spill area at disk cost,
    /// without re-promotion (blocks stay spilled; reads are never
    /// effective hits).
    ReadThrough,
}

impl RestorePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RestorePolicy::GroupPromote => "group_promote",
            RestorePolicy::ReadThrough => "read_through",
        }
    }
}

/// Second storage tier: demote evicted transform blocks to a per-worker
/// local-disk spill area (budget-bounded, §2 disk cost model) instead of
/// dropping the bytes. `EngineConfig::spill` is `None` by default — the
/// engines then behave exactly as before this tier existed.
///
/// With spill enabled, a transform block whose bytes leave both tiers
/// (demotion refused, spill-budget eviction) is **Dropped**: if a pending
/// task still needs it, the driver re-plans it through the lineage
/// machinery ([`crate::recovery`]) exactly like a failure-lost block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpillConfig {
    /// Per-worker spill-area budget in bytes. A budget of 0 never admits
    /// anything: every demotion drops, the pure-recompute baseline.
    pub budget_per_worker: u64,
    pub mode: SpillMode,
    pub restore: RestorePolicy,
}

impl SpillConfig {
    /// LERC-coordinated demotion with pre-dispatch group restore.
    pub fn coordinated(budget_per_worker: u64) -> Self {
        Self {
            budget_per_worker,
            mode: SpillMode::Coordinated,
            restore: RestorePolicy::GroupPromote,
        }
    }

    /// Naive per-block demotion (same restore policy, so the comparison
    /// isolates the demotion discipline).
    pub fn per_block(budget_per_worker: u64) -> Self {
        Self {
            budget_per_worker,
            mode: SpillMode::PerBlock,
            restore: RestorePolicy::GroupPromote,
        }
    }
}

/// Control-plane network model (driver <-> worker messages).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetConfig {
    /// One-way latency added per control message (default 0.5 ms — EC2
    /// same-AZ RTT/2 class). Lets Fig 5/7 reproduce the paper's
    /// small-cache communication-overhead effect.
    pub per_message_latency: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            per_message_latency: Duration::from_micros(500),
        }
    }
}

/// Per-worker NIC shape for the simulator's contended network model
/// (see [`NetModel::FairShare`] and `sim::network`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkConfig {
    /// Inbound bandwidth in bytes/second (default 125 MB/s — 1 Gbps,
    /// 2016-EC2 instance class).
    pub ingress_bytes_per_sec: u64,
    /// Outbound bandwidth in bytes/second.
    pub egress_bytes_per_sec: u64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        Self {
            ingress_bytes_per_sec: 125 * 1024 * 1024,
            egress_bytes_per_sec: 125 * 1024 * 1024,
        }
    }
}

/// Which data-path cost model the *simulator* charges for remote and
/// disk reads. The threaded engine always uses the flat §2 charges
/// (its concurrency is real, not modeled), so this knob is sim-only.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum NetModel {
    /// Flat per-read charges through `storage::tiered::read_cost`:
    /// every read costs the same whether or not the link is busy. The
    /// default, and the mode whose decisions/timings are pinned
    /// equivalent to the threaded engine (DESIGN.md §4).
    #[default]
    Flat,
    /// Contended fair-share links (DESIGN.md §6): each worker gets an
    /// ingress/egress NIC plus a disk channel, and concurrent remote
    /// reads, group restores, and recovery reloads sharing a link
    /// split its bandwidth, with completion times recomputed on every
    /// flow arrival/departure.
    FairShare(LinkConfig),
}

/// How the driver distributes control-plane state (ref counts, peer
/// profiles, eviction invalidations) to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CtrlPlane {
    /// Push every update to every worker, one message per event — the
    /// paper's §III-C/§IV accounting model. The figure-reproduction
    /// harness runs this mode so `MessageStats` match the paper's
    /// overhead experiments.
    Broadcast,
    /// Route each block's metadata only to its home worker (the only
    /// store whose eviction decisions can consume it), batch ref-count
    /// deltas per destination, and deliver eviction invalidations only
    /// to workers whose registered peer groups contain the block.
    /// Control traffic scales with useful updates instead of
    /// `workers × tasks`.
    HomeRouted,
}

impl CtrlPlane {
    pub fn name(&self) -> &'static str {
        match self {
            CtrlPlane::Broadcast => "broadcast",
            CtrlPlane::HomeRouted => "home_routed",
        }
    }
}

/// How `ShardedStore::get` serves resident blocks (DESIGN.md §7).
///
/// The *type* default is [`StoreReadPath::Locked`] — `ShardedStore::new`
/// and the single-threaded simulator keep the historical take-the-shard-
/// mutex read, whose eviction order the paper-exactness pins rely on.
/// [`EngineConfig::default`] selects [`StoreReadPath::Optimistic`] for the
/// threaded `ClusterEngine`, where reads are real concurrency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum StoreReadPath {
    /// Every read takes the owning shard's mutex and applies its policy
    /// touch inline — one global event order per shard, byte-identical
    /// to the pre-optimistic store.
    #[default]
    Locked,
    /// Reads are served off-lock from a seqlock-validated read-mostly
    /// index (payload + tier observed at one instant); policy touches
    /// are recorded in a per-shard lock-free ring and replayed in order
    /// under the shard lock at the next write/evict/pin_group drain
    /// (BP-Wrapper style). Program-order histories replay exactly; see
    /// `cache::sharded` for the exactness boundary.
    Optimistic,
}

impl StoreReadPath {
    pub fn name(&self) -> &'static str {
        match self {
            StoreReadPath::Locked => "locked",
            StoreReadPath::Optimistic => "optimistic",
        }
    }
}

/// How task compute executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ComputeMode {
    /// Run the AOT-compiled XLA artifact via the PJRT CPU client.
    Pjrt { artifacts_dir: PathBuf },
    /// Pure-Rust reference compute (used by the simulator, unit tests, and
    /// as a numerics cross-check against the PJRT path).
    Synthetic,
}

impl Default for ComputeMode {
    fn default() -> Self {
        ComputeMode::Synthetic
    }
}

/// Top-level engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Number of workers (the paper used 20 EC2 nodes).
    pub num_workers: u32,
    /// Memory-cache capacity per worker, in bytes.
    pub cache_capacity_per_worker: u64,
    /// Block length in f32 elements (must be a multiple of 1024 and have a
    /// matching AOT artifact when `compute` is Pjrt).
    pub block_len: usize,
    /// Eviction policy under test.
    pub policy: PolicyKind,
    pub disk: DiskConfig,
    pub mem: MemConfig,
    pub net: NetConfig,
    pub compute: ComputeMode,
    /// If true, task output persistence blocks the task (synchronous
    /// write-through). Default false: outputs are cached and flushed to
    /// disk off the critical path (Spark-style async writer).
    pub sync_output_writes: bool,
    /// Directory for the disk tier's block files (tempdir if None).
    pub disk_dir: Option<PathBuf>,
    /// Deterministic seed for input data + any tie-breaking randomness.
    pub seed: u64,
    /// Multiplier on modeled I/O / network sleeps in the threaded engine
    /// (1.0 = real-time HDD model; smaller = faster experiments with the
    /// same relative geometry). Reported makespans divide this back out.
    pub time_scale: f64,
    /// If true, tasks may start while ingest is still running (ablation
    /// knob; the paper's experiment ingests fully first).
    pub overlap_ingest: bool,
    /// Lock-striped shards per worker block store (rounded up to a power
    /// of two; 0 is treated as 1). The default of 1 keeps one policy
    /// instance with the exact global eviction order the paper
    /// experiments compare; larger values trade eviction precision for
    /// concurrent throughput (see `cache::sharded`).
    pub cache_shards: usize,
    /// Control-plane distribution strategy (see [`CtrlPlane`]). The
    /// default is [`CtrlPlane::HomeRouted`]; the paper-figure harness
    /// pins [`CtrlPlane::Broadcast`] for §IV-comparable message counts.
    pub ctrl_plane: CtrlPlane,
    /// Deterministic worker kill/restart schedule (empty = fault-free).
    /// Interpreted identically by the threaded engine and the simulator;
    /// see [`crate::recovery`] and DESIGN.md §3. Superseded by
    /// [`EngineConfig::topology`], which also expresses joins and
    /// autoscaling; a non-empty `failures` plan is upgraded losslessly
    /// through [`EngineConfig::effective_topology`] when `topology` is
    /// unset (setting both is a build error).
    pub failures: FailurePlan,
    /// Deterministic elastic-topology schedule — kills, restarts, joins,
    /// or the cache-aware autoscale policy (DESIGN.md §9). The default
    /// empty plan leaves the fleet static; both engines resolve the run's
    /// effective plan via [`EngineConfig::effective_topology`].
    pub topology: TopologyPlan,
    /// Memory → local-disk spill tier (DESIGN.md §5). `None` (default)
    /// disables the tier entirely: evictions drop bytes and every report
    /// is byte-identical to the pre-spill engine.
    pub spill: Option<SpillConfig>,
    /// Simulator data-path network model (see [`NetModel`]). The default
    /// [`NetModel::Flat`] keeps the flat §2 read charges; the threaded
    /// engine ignores this field.
    pub net_model: NetModel,
    /// Read path for the threaded engine's per-worker block stores (see
    /// [`StoreReadPath`]). Defaults to [`StoreReadPath::Optimistic`];
    /// the single-threaded simulator always runs Locked semantics
    /// regardless of this field, keeping its tick stream byte-identical.
    pub read_path: StoreReadPath,
    /// Capacity (entries, rounded up to a power of two) of each shard's
    /// deferred-touch ring on the Optimistic read path. A full ring makes
    /// the reader fall back to a locked drain, so this bounds touch lag,
    /// not correctness. Ignored under [`StoreReadPath::Locked`].
    pub read_touch_buffer: usize,
    /// Flight recorder (DESIGN.md §8). The default `Off` is free: every
    /// emission site is one branch, no event is constructed, and reports
    /// are byte-identical to a tracing run (pinned by `tests/trace.rs`).
    pub trace: crate::trace::TraceConfig,
    /// Continuous telemetry sampler (DESIGN.md §10). `None` (default)
    /// leaves `RunReport::timeline` empty. Deliberately independent of
    /// [`Self::trace`]: the Off-vs-Collect byte-identity invariant
    /// compares reports, so the sampler must not ride the trace switch.
    pub timeline: Option<TimelineConfig>,
}

/// Telemetry-sampler knobs (DESIGN.md §10). Samples are taken at
/// dispatch boundaries — the deterministic clock both engines share —
/// so the simulator's timeline is bit-reproducible across repeats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimelineConfig {
    /// Take one sample every N task dispatches (plus one final sample
    /// at teardown). Must be nonzero.
    pub every_dispatches: u64,
}

impl Default for TimelineConfig {
    fn default() -> Self {
        Self { every_dispatches: 64 }
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            num_workers: 4,
            cache_capacity_per_worker: 16 * 1024 * 1024,
            block_len: 65536,
            policy: PolicyKind::Lerc,
            disk: DiskConfig::default(),
            mem: MemConfig::default(),
            net: NetConfig::default(),
            compute: ComputeMode::Synthetic,
            sync_output_writes: false,
            disk_dir: None,
            seed: 17,
            time_scale: 1.0,
            overlap_ingest: false,
            cache_shards: 1,
            ctrl_plane: CtrlPlane::HomeRouted,
            failures: FailurePlan::none(),
            topology: TopologyPlan::none(),
            spill: None,
            net_model: NetModel::Flat,
            read_path: StoreReadPath::Optimistic,
            read_touch_buffer: 1024,
            trace: crate::trace::TraceConfig::Off,
            timeline: None,
        }
    }
}

impl EngineConfig {
    /// Bytes per block (f32 payload).
    pub fn block_bytes(&self) -> u64 {
        (self.block_len * std::mem::size_of::<f32>()) as u64
    }

    /// Total cluster cache capacity.
    pub fn total_cache(&self) -> u64 {
        self.cache_capacity_per_worker * self.num_workers as u64
    }

    /// How many blocks fit in one worker's cache.
    pub fn blocks_per_worker_cache(&self) -> u64 {
        self.cache_capacity_per_worker / self.block_bytes().max(1)
    }

    /// Start a validating [`EngineConfigBuilder`] seeded with the
    /// defaults. `build()` rejects nonsense combinations up front
    /// instead of letting them surface mid-run.
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder::default()
    }

    /// The run's effective topology plan: the explicit [`Self::topology`]
    /// if non-empty, else the legacy [`Self::failures`] schedule upgraded
    /// losslessly. Both engines resolve through this one path, so a
    /// kill/restart-only config behaves byte-identically whichever field
    /// carries it.
    pub fn effective_topology(&self) -> TopologyPlan {
        if self.topology.is_empty() {
            self.failures.clone().into()
        } else {
            self.topology.clone()
        }
    }

    /// The fleet's worker-slot ceiling (placement modulus, store-vector
    /// and trace-track sizing): `num_workers` unless the topology plan
    /// joins slots beyond it. See [`TopologyPlan::ceiling`].
    pub fn worker_ceiling(&self) -> u32 {
        self.effective_topology().ceiling(self.num_workers)
    }

    /// Hard sanity checks every engine runs before executing (the
    /// builder layers stricter ergonomic checks on top of these).
    pub fn validate(&self) -> crate::common::error::Result<()> {
        use crate::common::error::EngineError;
        if self.num_workers == 0 {
            return Err(EngineError::Config("num_workers must be at least 1".into()));
        }
        if self.block_len == 0 {
            return Err(EngineError::Config("block_len must be nonzero".into()));
        }
        if !self.time_scale.is_finite() || self.time_scale <= 0.0 {
            return Err(EngineError::Config(format!(
                "time_scale must be a positive finite number, got {}",
                self.time_scale
            )));
        }
        if let NetModel::FairShare(link) = self.net_model {
            if link.ingress_bytes_per_sec == 0 || link.egress_bytes_per_sec == 0 {
                return Err(EngineError::Config(
                    "fair-share network model needs nonzero ingress/egress bandwidth".into(),
                ));
            }
            if !self.disk.unthrottled && self.disk.bandwidth_bytes_per_sec == 0 {
                return Err(EngineError::Config(
                    "fair-share network model needs nonzero disk bandwidth \
                     (or an unthrottled disk)"
                        .into(),
                ));
            }
        }
        if let Some(t) = self.timeline {
            if t.every_dispatches == 0 {
                return Err(EngineError::Config(
                    "timeline sampler needs a nonzero every_dispatches \
                     (dispatches between samples)"
                        .into(),
                ));
            }
        }
        if self.read_path == StoreReadPath::Optimistic && self.read_touch_buffer == 0 {
            return Err(EngineError::Config(
                "the Optimistic read path needs a nonzero read_touch_buffer \
                 (entries per shard, rounded up to a power of two)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Divide a measured duration back out by `time_scale`, so every
    /// reported duration — makespans, per-job JCTs, recovery time —
    /// normalizes through one code path.
    pub fn unscale(&self, d: Duration) -> Duration {
        d.div_f64(self.time_scale)
    }
}

/// Validating builder for [`EngineConfig`] — the front door for tests,
/// benches, and examples (struct literals with `..Default::default()`
/// still work, but skip validation until the engine runs).
///
/// `build()` runs [`EngineConfig::validate`] plus stricter ergonomic
/// checks: a spill budget smaller than one block (admits nothing while
/// looking enabled) is refused here. Queue-level rules that need the
/// workload — notably `pinned_cache` being single-job only — stay in
/// [`crate::workload::JobQueue::validate`], which every engine calls.
#[derive(Debug, Clone, Default)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    pub fn num_workers(mut self, n: u32) -> Self {
        self.cfg.num_workers = n;
        self
    }

    pub fn cache_capacity_per_worker(mut self, bytes: u64) -> Self {
        self.cfg.cache_capacity_per_worker = bytes;
        self
    }

    /// Per-worker cache capacity in *blocks* of the currently-set
    /// `block_len` — call after [`Self::block_len`].
    pub fn cache_blocks(mut self, blocks: u64) -> Self {
        self.cfg.cache_capacity_per_worker = blocks * self.cfg.block_bytes();
        self
    }

    pub fn block_len(mut self, len: usize) -> Self {
        self.cfg.block_len = len;
        self
    }

    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn disk(mut self, disk: DiskConfig) -> Self {
        self.cfg.disk = disk;
        self
    }

    pub fn mem(mut self, mem: MemConfig) -> Self {
        self.cfg.mem = mem;
        self
    }

    pub fn net(mut self, net: NetConfig) -> Self {
        self.cfg.net = net;
        self
    }

    pub fn compute(mut self, compute: ComputeMode) -> Self {
        self.cfg.compute = compute;
        self
    }

    pub fn sync_output_writes(mut self, on: bool) -> Self {
        self.cfg.sync_output_writes = on;
        self
    }

    pub fn disk_dir(mut self, dir: PathBuf) -> Self {
        self.cfg.disk_dir = Some(dir);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn time_scale(mut self, scale: f64) -> Self {
        self.cfg.time_scale = scale;
        self
    }

    pub fn overlap_ingest(mut self, on: bool) -> Self {
        self.cfg.overlap_ingest = on;
        self
    }

    pub fn cache_shards(mut self, shards: usize) -> Self {
        self.cfg.cache_shards = shards;
        self
    }

    pub fn ctrl_plane(mut self, plane: CtrlPlane) -> Self {
        self.cfg.ctrl_plane = plane;
        self
    }

    /// Deterministic worker kill/restart schedule.
    #[deprecated(
        since = "0.1.0",
        note = "use `topology` — `TopologyPlan` subsumes kill/restart \
                schedules and adds joins and autoscaling"
    )]
    pub fn failures(mut self, plan: FailurePlan) -> Self {
        self.cfg.failures = plan;
        self
    }

    /// Deterministic elastic-topology schedule: kills, restarts, joins,
    /// or autoscale (DESIGN.md §9). Supersedes [`Self::failures`].
    pub fn topology(mut self, plan: TopologyPlan) -> Self {
        self.cfg.topology = plan;
        self
    }

    pub fn spill(mut self, spill: SpillConfig) -> Self {
        self.cfg.spill = Some(spill);
        self
    }

    pub fn net_model(mut self, model: NetModel) -> Self {
        self.cfg.net_model = model;
        self
    }

    pub fn read_path(mut self, path: StoreReadPath) -> Self {
        self.cfg.read_path = path;
        self
    }

    pub fn read_touch_buffer(mut self, entries: usize) -> Self {
        self.cfg.read_touch_buffer = entries;
        self
    }

    pub fn trace(mut self, trace: crate::trace::TraceConfig) -> Self {
        self.cfg.trace = trace;
        self
    }

    /// Continuous telemetry sampler (DESIGN.md §10); independent of the
    /// flight recorder so default reports stay byte-identical.
    pub fn timeline(mut self, timeline: TimelineConfig) -> Self {
        self.cfg.timeline = Some(timeline);
        self
    }

    pub fn build(self) -> crate::common::error::Result<EngineConfig> {
        use crate::common::error::EngineError;
        self.cfg.validate()?;
        if let Some(spill) = &self.cfg.spill {
            if spill.budget_per_worker > 0 && spill.budget_per_worker < self.cfg.block_bytes() {
                return Err(EngineError::Config(format!(
                    "spill budget_per_worker {} is smaller than one block ({} bytes): \
                     it admits nothing — use 0 for the explicit pure-recompute baseline",
                    spill.budget_per_worker,
                    self.cfg.block_bytes()
                )));
            }
        }
        if !self.cfg.failures.is_empty() && !self.cfg.topology.is_empty() {
            return Err(EngineError::Config(
                "both `failures` and `topology` are set: move the kill/restart \
                 schedule into the topology plan (From<FailurePlan> is lossless)"
                    .into(),
            ));
        }
        validate_topology(&self.cfg.topology, self.cfg.num_workers)?;
        Ok(self.cfg)
    }
}

/// Static sanity checks on a topology plan (builder-level, so nonsense
/// fails at `build()` instead of mid-run). `Events` plans: every join
/// must name a pending slot (at or beyond `num_workers`) and each slot
/// joins at most once; kills must name a slot that exists when they fire
/// (initial fleet or an earlier join). `Auto` plans: bounds must not be
/// inverted and the check period must be nonzero.
fn validate_topology(
    plan: &TopologyPlan,
    num_workers: u32,
) -> crate::common::error::Result<()> {
    use crate::common::error::EngineError;
    match plan {
        TopologyPlan::Events(_) => {
            let mut pending: Vec<u32> =
                (num_workers..plan.ceiling(num_workers)).collect();
            for e in plan.sorted_events() {
                match e {
                    TopologyEvent::Join { worker, .. } => {
                        if worker.0 < num_workers {
                            return Err(EngineError::Config(format!(
                                "topology join of worker {} which is alive from the start \
                                 (initial fleet is 0..{num_workers})",
                                worker.0
                            )));
                        }
                        if let Some(i) = pending.iter().position(|&p| p == worker.0) {
                            pending.swap_remove(i);
                        } else {
                            return Err(EngineError::Config(format!(
                                "topology join of worker {} twice — each slot joins at \
                                 most once (use Kill + restart_after for churn)",
                                worker.0
                            )));
                        }
                    }
                    TopologyEvent::Kill { worker, .. } => {
                        if pending.contains(&worker.0) {
                            return Err(EngineError::Config(format!(
                                "topology kill of worker {} before its join fires \
                                 (the slot is still pending at dispatch {})",
                                worker.0,
                                e.at_dispatch()
                            )));
                        }
                    }
                }
            }
        }
        TopologyPlan::Auto(a) => {
            if a.min_workers == 0 {
                return Err(EngineError::Config(
                    "autoscale min_workers must be at least 1".into(),
                ));
            }
            if a.min_workers > a.max_workers {
                return Err(EngineError::Config(format!(
                    "autoscale bounds inverted: min_workers {} > max_workers {}",
                    a.min_workers, a.max_workers
                )));
            }
            if a.mem_low > a.mem_high {
                return Err(EngineError::Config(format!(
                    "autoscale memory thresholds inverted: mem_low {} > mem_high {}",
                    a.mem_low, a.mem_high
                )));
            }
            if a.check_every == 0 {
                return Err(EngineError::Config(
                    "autoscale check_every must be nonzero dispatches".into(),
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disk_cost_is_seek_plus_transfer() {
        let d = DiskConfig {
            bandwidth_bytes_per_sec: 100 * 1024 * 1024,
            seek_latency: Duration::from_millis(10),
            unthrottled: false,
        };
        let c = d.io_cost(100 * 1024 * 1024);
        assert_eq!(c, Duration::from_millis(10) + Duration::from_secs(1));
    }

    #[test]
    fn unthrottled_costs_zero() {
        let d = DiskConfig {
            unthrottled: true,
            ..Default::default()
        };
        assert_eq!(d.io_cost(u64::MAX / 2), Duration::ZERO);
    }

    #[test]
    fn policy_classification() {
        assert!(PolicyKind::Lerc.dag_aware());
        assert!(PolicyKind::Lerc.peer_aware());
        assert!(PolicyKind::Lrc.dag_aware());
        assert!(!PolicyKind::Lrc.peer_aware());
        assert!(!PolicyKind::Lru.dag_aware());
        assert_eq!(PolicyKind::PAPER.len(), 3);
    }

    #[test]
    fn spill_is_off_by_default_and_builders_set_modes() {
        assert!(EngineConfig::default().spill.is_none());
        let c = SpillConfig::coordinated(1024);
        assert_eq!(c.mode, SpillMode::Coordinated);
        assert_eq!(c.restore, RestorePolicy::GroupPromote);
        assert_eq!(c.budget_per_worker, 1024);
        let p = SpillConfig::per_block(2048);
        assert_eq!(p.mode, SpillMode::PerBlock);
        assert_eq!(p.restore, RestorePolicy::GroupPromote);
        assert_eq!(SpillMode::Coordinated.name(), "coordinated");
        assert_eq!(RestorePolicy::ReadThrough.name(), "read_through");
    }

    #[test]
    fn builder_builds_defaults_and_setters_stick() {
        let cfg = EngineConfig::builder().build().unwrap();
        assert_eq!(cfg.num_workers, EngineConfig::default().num_workers);
        let cfg = EngineConfig::builder()
            .num_workers(8)
            .block_len(4096)
            .cache_blocks(6)
            .policy(PolicyKind::Lru)
            .time_scale(0.25)
            .spill(SpillConfig::coordinated(4096 * 4 * 2))
            .net_model(NetModel::FairShare(LinkConfig::default()))
            .build()
            .unwrap();
        assert_eq!(cfg.num_workers, 8);
        assert_eq!(cfg.cache_capacity_per_worker, 6 * 4096 * 4);
        assert_eq!(cfg.blocks_per_worker_cache(), 6);
        assert!(matches!(cfg.net_model, NetModel::FairShare(_)));
    }

    #[test]
    fn builder_rejects_nonsense() {
        assert!(EngineConfig::builder().num_workers(0).build().is_err());
        assert!(EngineConfig::builder().block_len(0).build().is_err());
        assert!(EngineConfig::builder().time_scale(0.0).build().is_err());
        assert!(EngineConfig::builder().time_scale(f64::NAN).build().is_err());
        // A spill budget below one block admits nothing: refused (0 is
        // the explicit pure-recompute baseline and stays allowed).
        let sub_block = EngineConfig::builder()
            .block_len(4096)
            .spill(SpillConfig::coordinated(100))
            .build();
        assert!(sub_block.is_err());
        assert!(EngineConfig::builder()
            .block_len(4096)
            .spill(SpillConfig::coordinated(0))
            .build()
            .is_ok());
        let zero_link = EngineConfig::builder()
            .net_model(NetModel::FairShare(LinkConfig {
                ingress_bytes_per_sec: 0,
                egress_bytes_per_sec: 1,
            }))
            .build();
        assert!(zero_link.is_err());
    }

    #[test]
    fn topology_validation_rejects_nonsense_plans() {
        use crate::common::ids::WorkerId;
        use crate::recovery::plan::AutoscaleConfig;
        // Joining a worker that is alive from the start.
        assert!(EngineConfig::builder()
            .num_workers(4)
            .topology(TopologyPlan::join_at(2, 5))
            .build()
            .is_err());
        // Joining the same pending slot twice.
        let twice = TopologyPlan::join_at(4, 5).then(TopologyEvent::Join {
            worker: WorkerId(4),
            at_dispatch: 9,
        });
        assert!(EngineConfig::builder().num_workers(4).topology(twice).build().is_err());
        // Killing a pending slot before its join fires.
        let early_kill = TopologyPlan::join_at(4, 9).then(TopologyEvent::Kill {
            worker: WorkerId(4),
            at_dispatch: 3,
            restart_after: None,
        });
        assert!(EngineConfig::builder()
            .num_workers(4)
            .topology(early_kill)
            .build()
            .is_err());
        // Kill *after* the join is fine, as is a plain pending join.
        let join_then_kill = TopologyPlan::join_at(4, 3).then(TopologyEvent::Kill {
            worker: WorkerId(4),
            at_dispatch: 9,
            restart_after: None,
        });
        assert!(EngineConfig::builder()
            .num_workers(4)
            .topology(join_then_kill)
            .build()
            .is_ok());
        // Inverted autoscale bounds.
        for bad in [
            AutoscaleConfig {
                min_workers: 5,
                max_workers: 2,
                ..Default::default()
            },
            AutoscaleConfig {
                mem_low: 0.9,
                mem_high: 0.2,
                ..Default::default()
            },
            AutoscaleConfig {
                check_every: 0,
                ..Default::default()
            },
            AutoscaleConfig {
                min_workers: 0,
                ..Default::default()
            },
        ] {
            assert!(EngineConfig::builder()
                .num_workers(2)
                .topology(TopologyPlan::Auto(bad))
                .build()
                .is_err());
        }
        assert!(EngineConfig::builder()
            .num_workers(2)
            .topology(TopologyPlan::Auto(AutoscaleConfig::default()))
            .build()
            .is_ok());
    }

    #[test]
    #[allow(deprecated)]
    fn failure_plan_compat_resolves_through_effective_topology() {
        // The deprecated builder path still works...
        let cfg = EngineConfig::builder()
            .num_workers(4)
            .failures(FailurePlan::kill_at(1, 10))
            .build()
            .unwrap();
        // ...and resolves to the same effective plan as the new field.
        assert_eq!(
            cfg.effective_topology(),
            TopologyPlan::from(FailurePlan::kill_at(1, 10))
        );
        assert_eq!(cfg.worker_ceiling(), 4, "no joins: ceiling is the fleet");
        // Setting both is refused.
        assert!(EngineConfig::builder()
            .num_workers(6)
            .failures(FailurePlan::kill_at(1, 10))
            .topology(TopologyPlan::join_at(6, 4))
            .build()
            .is_err());
        // A join plan raises the ceiling.
        let cfg = EngineConfig::builder()
            .num_workers(4)
            .topology(TopologyPlan::join_at(5, 4))
            .build()
            .unwrap();
        assert_eq!(cfg.worker_ceiling(), 6);
    }

    #[test]
    fn validate_is_the_engines_front_gate() {
        let mut cfg = EngineConfig::default();
        cfg.validate().unwrap();
        cfg.time_scale = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn read_path_defaults_and_validation() {
        // The *type* default is Locked (paper exactness); the *engine
        // config* default is Optimistic (threaded throughput).
        assert_eq!(StoreReadPath::default(), StoreReadPath::Locked);
        assert_eq!(EngineConfig::default().read_path, StoreReadPath::Optimistic);
        assert_eq!(StoreReadPath::Locked.name(), "locked");
        assert_eq!(StoreReadPath::Optimistic.name(), "optimistic");

        let cfg = EngineConfig::builder()
            .read_path(StoreReadPath::Locked)
            .read_touch_buffer(0)
            .build()
            .unwrap();
        assert_eq!(cfg.read_path, StoreReadPath::Locked);
        // A zero touch buffer is only nonsense when Optimistic needs it.
        assert!(EngineConfig::builder()
            .read_path(StoreReadPath::Optimistic)
            .read_touch_buffer(0)
            .build()
            .is_err());
        let cfg = EngineConfig::builder().read_touch_buffer(64).build().unwrap();
        assert_eq!(cfg.read_touch_buffer, 64);
    }

    #[test]
    fn unscale_divides_time_scale_back_out() {
        let cfg = EngineConfig {
            time_scale: 0.25,
            ..Default::default()
        };
        assert_eq!(cfg.unscale(Duration::from_secs(1)), Duration::from_secs(4));
        let unit = EngineConfig::default();
        assert_eq!(unit.unscale(Duration::from_secs(3)), Duration::from_secs(3));
    }

    #[test]
    fn config_block_math() {
        let cfg = EngineConfig {
            block_len: 65536,
            cache_capacity_per_worker: 1024 * 1024,
            num_workers: 3,
            ..Default::default()
        };
        assert_eq!(cfg.block_bytes(), 256 * 1024);
        assert_eq!(cfg.blocks_per_worker_cache(), 4);
        assert_eq!(cfg.total_cache(), 3 * 1024 * 1024);
    }
}
