//! Deterministic RNG (SplitMix64 + a gaussian approximation).
//!
//! All input data, workload arrival jitter and tie-breaking randomness in
//! the engine flows through this generator, so every experiment is exactly
//! reproducible from `EngineConfig::seed`.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent stream for a sub-entity (block, worker, ...).
    pub fn derive(&self, stream: u64) -> Self {
        let mut child = Self::new(self.state ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        child.next_u64(); // decorrelate
        Self::new(child.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-1, 1) — the block payload distribution.
    pub fn next_f32_signed(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free (slightly biased for huge
        // n, irrelevant for our n << 2^32).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }
}

/// Deterministic payload for block `index` of dataset `dataset_seed`:
/// `len` f32s in [-1, 1).
pub fn block_payload(seed: u64, dataset_seed: u64, index: u32, len: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed)
        .derive(dataset_seed)
        .derive(index as u64 + 1);
    (0..len).map(|_| rng.next_f32_signed()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SplitMix64::new(7).derive(3);
        let mut b = SplitMix64::new(7).derive(3);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = SplitMix64::new(7).derive(1);
        let mut b = SplitMix64::new(7).derive(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn payload_deterministic_and_bounded() {
        let p1 = block_payload(17, 5, 9, 4096);
        let p2 = block_payload(17, 5, 9, 4096);
        assert_eq!(p1, p2);
        assert!(p1.iter().all(|v| (-1.0..1.0).contains(v)));
        // Different block index -> different payload.
        let p3 = block_payload(17, 5, 10, 4096);
        assert_ne!(p1, p3);
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(rng.next_below(13) < 13);
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = SplitMix64::new(2);
        for _ in 0..1000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
