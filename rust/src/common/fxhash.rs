//! FxHash (the Firefox/rustc hash): a fast non-cryptographic hasher for
//! the engine's hot maps. Cache-policy and peer-tracker maps are keyed by
//! small ids (`BlockId` = 8 bytes) that we generate ourselves, so DoS
//! resistance is irrelevant and std's SipHash costs ~2× on the eviction
//! path (see EXPERIMENTS.md §Perf).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc-internal multiply-rotate hash.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add_to_hash(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add_to_hash(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{BlockId, DatasetId};

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<BlockId, u32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(BlockId::new(DatasetId(i % 7), i), i);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&BlockId::new(DatasetId(3), 3)], 3);

        let mut s: FxHashSet<u64> = FxHashSet::default();
        s.insert(42);
        assert!(s.contains(&42));
    }

    #[test]
    fn hash_distributes() {
        // Sequential block ids must not collide into few buckets: check
        // the low bits vary.
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let mut low_bits = FxHashSet::default();
        for i in 0..256u32 {
            let mut h = bh.build_hasher();
            BlockId::new(DatasetId(0), i).hash(&mut h);
            low_bits.insert(h.finish() & 0xFF);
        }
        assert!(low_bits.len() > 128, "only {} distinct buckets", low_bits.len());
    }
}
