//! Minimal self-cleaning temporary directory (the build is offline and
//! cannot use the `tempfile` crate). Used by tests, benches, and as the
//! engine's default disk-tier location.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp dir, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "lerc-{}-{}-{}",
            prefix,
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_unique_dirs_and_cleans_up() {
        let p1;
        {
            let d1 = TempDir::new("t").unwrap();
            let d2 = TempDir::new("t").unwrap();
            assert_ne!(d1.path(), d2.path());
            assert!(d1.path().is_dir());
            p1 = d1.path().to_path_buf();
            std::fs::write(d1.path().join("x"), b"y").unwrap();
        }
        assert!(!p1.exists());
    }
}
