//! Newtype ids for every entity in the engine.
//!
//! `BlockId` is the unit of caching (one partition of one dataset), exactly
//! the granularity the paper's policies operate on.

use std::fmt;

/// A logical dataset (Spark RDD analog) within a job DAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DatasetId(pub u32);

/// One partition (block) of a dataset — the unit of caching and eviction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId {
    pub dataset: DatasetId,
    pub index: u32,
}

impl BlockId {
    pub const fn new(dataset: DatasetId, index: u32) -> Self {
        Self { dataset, index }
    }
}

/// A compute task: materializes exactly one output block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u64);

/// A submitted job (one DAG; one tenant in the paper's §IV experiment).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

/// A worker node (executor) in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId(pub u32);

/// A peer-group: the set of input blocks of one task (paper §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GroupId(pub u64);

impl fmt::Display for DatasetId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "D{}", self.0)
    }
}

impl fmt::Display for BlockId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.dataset, self.index)
    }
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "T{}", self.0)
    }
}

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "J{}", self.0)
    }
}

impl fmt::Display for WorkerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "W{}", self.0)
    }
}

impl fmt::Display for GroupId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "G{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_id_ordering_is_dataset_major() {
        let a = BlockId::new(DatasetId(1), 9);
        let b = BlockId::new(DatasetId(2), 0);
        assert!(a < b);
    }

    #[test]
    fn display_formats() {
        assert_eq!(BlockId::new(DatasetId(3), 7).to_string(), "D3[7]");
        assert_eq!(TaskId(42).to_string(), "T42");
        assert_eq!(WorkerId(1).to_string(), "W1");
        assert_eq!(GroupId(5).to_string(), "G5");
        assert_eq!(JobId(2).to_string(), "J2");
    }

    #[test]
    fn ids_hash_and_eq() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(BlockId::new(DatasetId(0), 0));
        s.insert(BlockId::new(DatasetId(0), 0));
        assert_eq!(s.len(), 1);
    }
}
