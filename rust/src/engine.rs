//! The unified engine entry point.
//!
//! Both execution planes — the threaded [`crate::driver::ClusterEngine`]
//! and the event-driven [`crate::sim::Simulator`] — implement [`Engine`],
//! so tests, benches, and examples parametrize over engines instead of
//! duplicating call sites. The online multi-job queue is the primitive;
//! a single workload is the one-job convenience wrapper.

use crate::common::error::Result;
use crate::metrics::{FleetReport, RunReport};
use crate::workload::{JobQueue, Workload};

/// A cluster execution plane: runs an online job queue to completion
/// and reports per-job and aggregate metrics.
pub trait Engine {
    /// Run an online multi-job queue to completion: jobs admit at their
    /// arrival dispatch indices (or as soon as the cluster would
    /// otherwise quiesce), interleave dispatch by priority, and share
    /// the cache with cross-job effective reference counting.
    fn run(&self, queue: &JobQueue) -> Result<FleetReport>;

    /// One-job convenience wrapper: a queue of one job arriving at
    /// dispatch 0 (the classic offline run).
    fn run_workload(&self, workload: &Workload) -> Result<RunReport> {
        self.run(&JobQueue::single(workload.clone())).map(|fleet| fleet.aggregate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::ClusterEngine;
    use crate::sim::Simulator;
    use crate::{workload, EngineConfig};

    fn cfg() -> EngineConfig {
        EngineConfig::builder()
            .num_workers(2)
            .block_len(1024)
            .cache_blocks(6)
            .build()
            .unwrap()
    }

    #[test]
    fn both_engines_run_through_the_trait() {
        let w = workload::zip_single(4, 1024);
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(Simulator::from_engine_config(cfg())),
            Box::new(ClusterEngine::new(cfg())),
        ];
        for engine in &engines {
            let report = engine.run_workload(&w).unwrap();
            assert_eq!(report.tasks_run, 4);
            let fleet = engine.run(&JobQueue::single(w.clone())).unwrap();
            assert_eq!(fleet.aggregate.tasks_run, 4);
            assert_eq!(fleet.jobs.len(), 1);
        }
    }
}
