//! Control-plane bench: Broadcast vs HomeRouted on the threaded engine.
//!
//! Runs the same multi-tenant zip workload through `ClusterEngine` in
//! both `CtrlPlane` modes at 1/2/4/8 workers with every modeled cost
//! zeroed (unthrottled disk, zero net latency, infinite memory
//! bandwidth), so the measured tasks/sec is pure engine overhead — the
//! driver's send fan-out, worker wakeups, and queue traffic that this
//! control plane exists to shrink.
//!
//! Emits `BENCH_ctrl_plane.json` (path overridable via `BENCH_OUT`).
//! Headline figures:
//! * `ctrl_msgs_per_task` — per worker count: constant for HomeRouted,
//!   linear in workers for Broadcast.
//! * `speedup_at_4` — HomeRouted tasks/sec ÷ Broadcast tasks/sec at 4
//!   workers (the CI guard tracks this ratio; it is machine-portable
//!   where absolute tasks/sec is not).
//!
//! Reduced configuration for CI smoke runs: `CTRL_BENCH_QUICK=1`.

use lerc_engine::Engine;
use lerc_engine::common::config::{
    CtrlPlane, DiskConfig, EngineConfig, MemConfig, NetConfig, PolicyKind,
};
use lerc_engine::driver::ClusterEngine;
use lerc_engine::workload;
use std::fmt::Write as _;
use std::time::Duration;

#[derive(Debug, Clone)]
struct Row {
    mode: &'static str,
    workers: u32,
    tasks: u64,
    secs: f64,
    tasks_per_sec: f64,
    /// Driver → worker control messages attributable to cache metadata
    /// (ref-count updates + invalidation deliveries) per task.
    ctrl_msgs_per_task: f64,
}

fn cfg(mode: CtrlPlane, workers: u32, cache_blocks: u64, block_len: usize) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(block_len)
        .cache_blocks(cache_blocks)
        .policy(PolicyKind::Lerc)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .mem(MemConfig {
            bandwidth_bytes_per_sec: u64::MAX,
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .ctrl_plane(mode)
        .build()
        .expect("valid config")
}

fn bench_case(
    mode: CtrlPlane,
    workers: u32,
    tenants: u32,
    blocks: u32,
    block_len: usize,
    iters: usize,
) -> Row {
    let w = workload::multi_tenant_zip(tenants, blocks, block_len);
    // Cache sized to ~2/3 of each worker's share of the input: real
    // eviction pressure, so invalidation traffic flows too.
    let total_blocks = (tenants * blocks * 2) as u64;
    let cache_blocks = (total_blocks * 2 / 3 / workers as u64).max(2);
    let mut best: Option<Row> = None;
    for _ in 0..iters {
        let report = ClusterEngine::new(cfg(mode, workers, cache_blocks, block_len))
            .run_workload(&w)
            .expect("bench run");
        let secs = report.compute_makespan.as_secs_f64().max(1e-9);
        let m = &report.messages;
        let ctrl_msgs = m.refcount_updates + m.broadcast_deliveries;
        let row = Row {
            mode: mode.name(),
            workers,
            tasks: report.tasks_run,
            secs,
            tasks_per_sec: report.tasks_run as f64 / secs,
            ctrl_msgs_per_task: ctrl_msgs as f64 / report.tasks_run.max(1) as f64,
        };
        if best.as_ref().map(|b| row.tasks_per_sec > b.tasks_per_sec).unwrap_or(true) {
            best = Some(row);
        }
    }
    best.expect("at least one iteration")
}

fn main() {
    let quick = std::env::var("CTRL_BENCH_QUICK").is_ok();
    let (tenants, blocks, iters) = if quick { (4u32, 24u32, 2usize) } else { (8, 48, 3) };
    let block_len = 1024usize;

    println!("ctrl_plane: multi_tenant_zip(t={tenants}, b={blocks}), {iters} iters, best-of\n");
    println!("| mode | workers | tasks | secs | tasks/sec | ctrl msgs/task |");
    println!("|---|---|---|---|---|---|");
    let mut rows: Vec<Row> = Vec::new();
    for &workers in &[1u32, 2, 4, 8] {
        for mode in [CtrlPlane::Broadcast, CtrlPlane::HomeRouted] {
            let row = bench_case(mode, workers, tenants, blocks, block_len, iters);
            println!(
                "| {} | {} | {} | {:.4} | {:.0} | {:.2} |",
                row.mode,
                row.workers,
                row.tasks,
                row.secs,
                row.tasks_per_sec,
                row.ctrl_msgs_per_task
            );
            rows.push(row);
        }
    }

    let at = |mode: &str, workers: u32| {
        rows.iter()
            .find(|r| r.mode == mode && r.workers == workers)
            .expect("row present")
    };
    let speedup_at_4 = at("home_routed", 4).tasks_per_sec / at("broadcast", 4).tasks_per_sec;
    let msgs_b_1 = at("broadcast", 1).ctrl_msgs_per_task;
    let msgs_b_8 = at("broadcast", 8).ctrl_msgs_per_task;
    let msgs_h_1 = at("home_routed", 1).ctrl_msgs_per_task;
    let msgs_h_8 = at("home_routed", 8).ctrl_msgs_per_task;
    println!(
        "\nhome_routed/broadcast tasks/sec at 4 workers: {speedup_at_4:.2}x\n\
         ctrl msgs/task 1→8 workers: broadcast {msgs_b_1:.2}→{msgs_b_8:.2}, \
         home_routed {msgs_h_1:.2}→{msgs_h_8:.2}"
    );
    // Hand-rolled JSON (no serde in the offline build). Written BEFORE
    // the invariant assertions so a failing run still leaves its per-row
    // data behind for diagnosis (CI uploads the artifact even on failure).
    let mut json = String::from("{\n  \"bench\": \"ctrl_plane\",\n");
    let _ = writeln!(json, "  \"tenants\": {tenants},");
    let _ = writeln!(json, "  \"blocks_per_file\": {blocks},");
    let _ = writeln!(json, "  \"speedup_at_4\": {speedup_at_4:.4},");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"mode\": \"{}\", \"workers\": {}, \"tasks\": {}, \"secs\": {:.6}, \
             \"tasks_per_sec\": {:.1}, \"ctrl_msgs_per_task\": {:.4}}}",
            r.mode, r.workers, r.tasks, r.secs, r.tasks_per_sec, r.ctrl_msgs_per_task
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_ctrl_plane.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // The routing invariant the bench exists to demonstrate: broadcast
    // traffic scales with the cluster, home-routed traffic does not. A
    // zip task's two inputs share one home, so home-routed traffic is at
    // most one ref-count message plus ~one invalidation delivery per
    // task at ANY worker count; broadcast pays that times the cluster.
    assert!(
        msgs_b_8 > msgs_b_1 * 4.0,
        "broadcast ctrl traffic should grow ~linearly with workers \
         ({msgs_b_1:.2} at 1w vs {msgs_b_8:.2} at 8w)"
    );
    assert!(
        msgs_h_8 <= 3.0 && msgs_h_1 <= 3.0,
        "home-routed ctrl traffic must stay ~constant per task \
         ({msgs_h_1:.2} at 1w vs {msgs_h_8:.2} at 8w)"
    );
    assert!(
        msgs_b_8 >= msgs_h_8 * 4.0,
        "at 8 workers, home routing should cut ctrl traffic well below broadcast \
         ({msgs_h_8:.2} vs {msgs_b_8:.2})"
    );
    // Acceptance target: >=1.3x tasks/sec at 4 workers. Quick/CI runs on
    // starved runners only warn; full runs enforce it.
    if speedup_at_4 < 1.3 {
        let msg = format!(
            "home_routed tasks/sec at 4 workers is {speedup_at_4:.2}x broadcast (target >=1.3x)"
        );
        if quick {
            eprintln!("WARNING: {msg}");
        } else {
            panic!("{msg}");
        }
    }

    println!("\nctrl_plane done");
}
