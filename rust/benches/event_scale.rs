//! Event-core scale bench: wide map-chain workloads on the
//! discrete-event simulator, up to 2,000 workers / 1,000,000 tasks.
//!
//! The guarded claim is the ISSUE-6 acceptance bound: the 2,000-worker /
//! 1M-task cell must simulate in under 30 seconds of wall clock on CI
//! (`wall_s_2000w_1m`, a `min_delta` ceiling in the baselines manifest —
//! an absolute bound, not a drift band, because wall clock on shared
//! runners is noisy but the event core being accidentally quadratic is
//! not noise). A fair-share cell exercises the contended network model
//! at fleet scale and reports its link-utilization stats.
//!
//! Emits `BENCH_event_scale.json` (path overridable via `BENCH_OUT`),
//! guarded in CI by `tools/bench_guard.py` via the baselines manifest.
//! `EVENT_SCALE_QUICK=1` trims the warm-up cells but ALWAYS keeps the
//! guarded 2,000-worker cell — a smoke run that skipped it would guard
//! nothing.

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, LinkConfig, NetModel, PolicyKind};
use lerc_engine::sim::Simulator;
use lerc_engine::workload;
use std::fmt::Write as _;
use std::time::Instant;

struct Row {
    label: &'static str,
    workers: u32,
    tasks: u64,
    wall_s: f64,
    tasks_per_s: f64,
    makespan_s: f64,
    net_flows: u64,
    mean_queueing_ms: f64,
    max_link_util: f64,
}

fn run_cell(
    label: &'static str,
    workers: u32,
    width: u32,
    depth: u32,
    policy: PolicyKind,
    net_model: NetModel,
) -> Row {
    let w = workload::scale_map_chain(width, depth, 256);
    let expected = (width as u64) * (depth as u64);
    let cfg = EngineConfig::builder()
        .num_workers(workers)
        .block_len(256)
        .cache_blocks(6)
        .policy(policy)
        .net_model(net_model)
        .build()
        .expect("valid config");
    let started = Instant::now();
    let r = Simulator::from_engine_config(cfg).run_workload(&w).expect("scale run");
    let wall_s = started.elapsed().as_secs_f64();
    assert_eq!(r.tasks_run, expected, "{label}: every task ran exactly once");
    Row {
        label,
        workers,
        tasks: expected,
        wall_s,
        tasks_per_s: expected as f64 / wall_s.max(1e-9),
        makespan_s: r.makespan.as_secs_f64(),
        net_flows: r.net.flows,
        mean_queueing_ms: r.net.mean_queueing_delay().as_secs_f64() * 1e3,
        max_link_util: r.net.max_link_utilization,
    }
}

fn main() {
    let quick = std::env::var("EVENT_SCALE_QUICK").is_ok();

    println!("event_scale: discrete-event core, wide map chains\n");
    println!(
        "| cell | workers | tasks | wall (s) | tasks/s | modeled makespan (s) \
         | flows | mean queue (ms) | max link util |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut rows: Vec<Row> = Vec::new();
    let mut cells: Vec<(&'static str, u32, u32, u32, PolicyKind, NetModel)> = Vec::new();
    if !quick {
        // Warm-up cells: broadcast-heavy LERC at small scale, then a
        // mid-size flat cell.
        cells.push(("flat_100w_20k", 100, 400, 50, PolicyKind::Lerc, NetModel::Flat));
        cells.push(("flat_500w_100k", 500, 1000, 100, PolicyKind::Lru, NetModel::Flat));
    }
    // The guarded cell: 2,000 workers, 1M tasks, flat charges.
    cells.push(("flat_2000w_1m", 2000, 4000, 250, PolicyKind::Lru, NetModel::Flat));
    // Fair-share at fleet scale: every read becomes a contended flow.
    cells.push((
        "fair_200w_20k",
        200,
        400,
        50,
        PolicyKind::Lru,
        NetModel::FairShare(LinkConfig::default()),
    ));

    for (label, workers, width, depth, policy, net_model) in cells {
        let row = run_cell(label, workers, width, depth, policy, net_model);
        println!(
            "| {} | {} | {} | {:.3} | {:.0} | {:.3} | {} | {:.3} | {:.3} |",
            row.label,
            row.workers,
            row.tasks,
            row.wall_s,
            row.tasks_per_s,
            row.makespan_s,
            row.net_flows,
            row.mean_queueing_ms,
            row.max_link_util
        );
        rows.push(row);
    }

    let big = rows
        .iter()
        .find(|r| r.label == "flat_2000w_1m")
        .expect("guarded cell always runs");
    let fair = rows.iter().find(|r| r.label == "fair_200w_20k").expect("fair cell always runs");
    println!(
        "\n2000 workers / 1M tasks: {:.2}s wall ({:.0} tasks/s); \
         fair-share cell: {} flows, max link util {:.3}",
        big.wall_s, big.tasks_per_s, fair.net_flows, fair.max_link_util
    );

    // JSON first, asserts after — a failing run still leaves its data
    // behind for diagnosis (CI uploads the artifact even on failure).
    let mut json = String::from("{\n  \"bench\": \"event_scale\",\n");
    let _ = writeln!(json, "  \"wall_s_2000w_1m\": {:.6},", big.wall_s);
    let _ = writeln!(json, "  \"tasks_per_s_2000w_1m\": {:.1},", big.tasks_per_s);
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"cell\": \"{}\", \"workers\": {}, \"tasks\": {}, \"wall_s\": {:.6}, \
             \"tasks_per_s\": {:.1}, \"makespan_s\": {:.6}, \"net_flows\": {}, \
             \"mean_queueing_ms\": {:.6}, \"max_link_util\": {:.6}}}",
            r.label,
            r.workers,
            r.tasks,
            r.wall_s,
            r.tasks_per_s,
            r.makespan_s,
            r.net_flows,
            r.mean_queueing_ms,
            r.max_link_util
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_event_scale.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // The fair-share model must actually have modeled contention in its
    // cell: flows crossed links and the stats landed on the report.
    assert!(fair.net_flows > 0, "fair-share cell recorded no flows");
    assert!(fair.max_link_util > 0.0, "fair-share cell recorded no link utilization");
    // Flat cells must report a zeroed network block (the old read-charge
    // semantics, byte-for-byte).
    assert_eq!(big.net_flows, 0, "flat cell must not model flows");

    println!("\nevent_scale done");
}
