//! Spill bench: LERC-coordinated spill vs naive per-block spill vs
//! no-spill (pure recompute) at three memory budgets, on the
//! deterministic simulator (machine-independent numbers).
//!
//! Workload: `double_map_zip_agg` — stage-2 peer groups pair co-located
//! *transform* blocks, so demotion and pre-dispatch restore both carry
//! real weight, and the consumed intermediates + sink blocks supply the
//! dead bytes that separate the disciplines. The spill budget covers the
//! needed in-transit volume: the coordinated mode (which refuses dead
//! bytes and never displaces a needed resident) recomputes little or
//! nothing, while the naive per-block mode wastes budget on dead bytes
//! and FIFO-drops blocks pending tasks still need — each such drop is a
//! lineage recompute.
//!
//! Emits `BENCH_spill.json` (path overridable via `BENCH_OUT`). Reduced
//! configuration for CI smoke runs: `SPILL_BENCH_QUICK=1`. The
//! manifest-driven guard (`tools/bench_guard.py`) tracks
//! `recompute_advantage_tightest` with a `min_delta` floor: coordinated
//! beating per-block is an invariant, not a tolerance band.

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind, SpillConfig};
use lerc_engine::metrics::RunReport;
use lerc_engine::sim::Simulator;
use lerc_engine::workload;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct Row {
    arm: &'static str,
    cache_blocks: u64,
    recomputes: u64,
    spilled: u64,
    restored: u64,
    restored_hits: u64,
    fallback_reads: u64,
    makespan_s: f64,
    effective_ratio: f64,
}

fn cfg(cache_blocks: u64, block_len: usize, spill: SpillConfig) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(2)
        .block_len(block_len)
        .cache_blocks(cache_blocks)
        .policy(PolicyKind::Lerc)
        .spill(spill)
        .build()
        .expect("valid config")
}

fn run(
    arm: &'static str,
    blocks: u32,
    block_len: usize,
    cache_blocks: u64,
    spill: SpillConfig,
) -> Row {
    let w = workload::double_map_zip_agg(blocks, block_len);
    let total = w.task_count() as u64;
    let r: RunReport = Simulator::from_engine_config(cfg(cache_blocks, block_len, spill))
        .run_workload(&w)
        .expect("spill bench run");
    assert_eq!(
        r.tasks_run,
        total + r.tier.spill_recompute_tasks,
        "{arm}: originals plus exactly the spill recomputes"
    );
    assert_eq!(
        r.access.accesses,
        r.access.mem_hits + r.tier.spill_reads + r.access.disk_reads,
        "{arm}: tiered conservation"
    );
    Row {
        arm,
        cache_blocks,
        recomputes: r.tier.spill_recompute_tasks,
        spilled: r.tier.spilled_blocks,
        restored: r.tier.restored_blocks,
        restored_hits: r.tier.restored_hits,
        fallback_reads: r.tier.fallback_durable_reads,
        makespan_s: r.compute_makespan.as_secs_f64(),
        effective_ratio: r.effective_hit_ratio(),
    }
}

fn main() {
    let quick = std::env::var("SPILL_BENCH_QUICK").is_ok();
    let (blocks, block_len) = if quick { (16u32, 4096usize) } else { (32, 16384) };
    // Per-worker spill budget sized to the needed in-transit volume (the
    // M/N stage of the DAG per worker): enough that a need-aware
    // discipline barely recomputes, small enough that wasting it on dead
    // bytes hurts.
    let budget = blocks as u64 * (block_len as u64) * 4;
    let mem_budgets: [u64; 3] = [2, 4, 8];

    println!(
        "spill: double_map_zip_agg(b={blocks}, len={block_len}), LERC, 2 workers, \
         spill budget {budget} B/worker\n"
    );
    println!(
        "| cache (blocks/worker) | arm | recomputes | spilled | restored | restored hits | \
         fallback reads | makespan (s) | eff ratio |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");

    let mut rows: Vec<Row> = Vec::new();
    for &cache in &mem_budgets {
        for (arm, spill) in [
            ("no_spill_recompute", SpillConfig::coordinated(0)),
            ("per_block", SpillConfig::per_block(budget)),
            ("coordinated", SpillConfig::coordinated(budget)),
        ] {
            let row = run(arm, blocks, block_len, cache, spill);
            println!(
                "| {} | {} | {} | {} | {} | {} | {} | {:.3} | {:.3} |",
                row.cache_blocks,
                row.arm,
                row.recomputes,
                row.spilled,
                row.restored,
                row.restored_hits,
                row.fallback_reads,
                row.makespan_s,
                row.effective_ratio
            );
            rows.push(row);
        }
    }

    let at = |arm: &str, cache: u64| {
        rows.iter()
            .find(|r| r.arm == arm && r.cache_blocks == cache)
            .expect("row present")
    };
    let tightest = mem_budgets[0];
    let advantage =
        at("per_block", tightest).recomputes as i64 - at("coordinated", tightest).recomputes as i64;

    // JSON first, asserts after — a failing run still leaves its data
    // behind for diagnosis (CI uploads the artifact even on failure).
    let mut json = String::from("{\n  \"bench\": \"spill\",\n");
    let _ = writeln!(json, "  \"blocks_per_file\": {blocks},");
    let _ = writeln!(json, "  \"block_len\": {block_len},");
    let _ = writeln!(json, "  \"spill_budget_bytes_per_worker\": {budget},");
    let _ = writeln!(json, "  \"recompute_advantage_tightest\": {advantage},");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"arm\": \"{}\", \"cache_blocks\": {}, \"recomputes\": {}, \
             \"spilled\": {}, \"restored\": {}, \"restored_hits\": {}, \
             \"fallback_reads\": {}, \"makespan_s\": {:.6}, \"effective_ratio\": {:.6}}}",
            r.arm,
            r.cache_blocks,
            r.recomputes,
            r.spilled,
            r.restored,
            r.restored_hits,
            r.fallback_reads,
            r.makespan_s,
            r.effective_ratio
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_spill.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\n(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // The claims this bench exists to demonstrate, on a deterministic
    // simulator (no flake room):
    // 1. Group-coordinated spill beats naive per-block spill on
    //    recompute count at the tightest memory budget.
    for &cache in &mem_budgets {
        assert!(
            at("coordinated", cache).recomputes <= at("per_block", cache).recomputes,
            "cache={cache}: coordinated must never recompute more than per-block"
        );
    }
    assert!(
        advantage > 0,
        "coordinated ({}) must beat per-block ({}) on recomputes at the tightest budget",
        at("coordinated", tightest).recomputes,
        at("per_block", tightest).recomputes
    );
    // 2. Both spill disciplines beat dropping the bytes outright.
    assert!(
        at("coordinated", tightest).recomputes < at("no_spill_recompute", tightest).recomputes,
        "a real budget must beat the pure-recompute baseline"
    );
    // 3. The coordinated tier actually moves groups both ways.
    assert!(at("coordinated", tightest).spilled > 0);
    assert!(at("coordinated", tightest).restored > 0);

    println!("\nspill bench done");
}
