//! Recovery bench: LERC vs LRU vs LRC job completion under a mid-job
//! worker kill (the ISSUE-3 failure scenario), on the deterministic
//! simulator so numbers are machine-independent.
//!
//! For each policy the same multi-tenant zip workload runs fault-free and
//! with a seeded kill of worker 1 at 50% of task dispatches. Headline
//! comparison: *ineffective hits* during the faulty run — LERC's
//! group-coherent cache keeps wasting less memory than LRU even while
//! lineage recovery churns the cluster.
//!
//! Emits `BENCH_recovery.json` (path overridable via `BENCH_OUT`).
//! Reduced configuration for CI smoke runs: `RECOVERY_BENCH_QUICK=1`.

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind};
use lerc_engine::metrics::RunReport;
use lerc_engine::recovery::FailurePlan;
use lerc_engine::sim::Simulator;
use lerc_engine::workload;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct Row {
    policy: &'static str,
    clean_s: f64,
    kill_s: f64,
    slowdown: f64,
    recovery_s: f64,
    blocks_lost: u64,
    recompute_tasks: u64,
    recompute_mib: f64,
    ineffective_hits: u64,
    effective_hit_ratio: f64,
}

fn cfg(policy: PolicyKind, workers: u32, cache_blocks: u64, block_len: usize) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(block_len)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .build()
        .expect("valid config")
}

fn run(policy: PolicyKind, tenants: u32, blocks: u32, block_len: usize) -> Row {
    let w = workload::multi_tenant_zip(tenants, blocks, block_len);
    let total = w.task_count() as u64;
    let workers = 4u32;
    // ~1/3 of the input fits: real pressure, the paper's interesting zone.
    let cache_blocks = ((tenants * blocks * 2) as u64 / 3 / workers as u64).max(2);

    let clean = Simulator::from_engine_config(cfg(policy, workers, cache_blocks, block_len))
        .run_workload(&w)
        .expect("clean run");
    let mut kcfg = cfg(policy, workers, cache_blocks, block_len);
    kcfg.failures = FailurePlan::kill_at(1, total / 2);
    let killed: RunReport =
        Simulator::from_engine_config(kcfg).run_workload(&w).expect("kill run");

    assert_eq!(clean.tasks_run, total, "{}", policy.name());
    assert_eq!(
        killed.tasks_run,
        total + killed.recovery.recompute_tasks,
        "{}: recompute closure only",
        policy.name()
    );
    assert_eq!(killed.recovery.workers_killed, 1);

    let clean_s = clean.compute_makespan.as_secs_f64();
    let kill_s = killed.compute_makespan.as_secs_f64();
    Row {
        policy: policy.name(),
        clean_s,
        kill_s,
        slowdown: kill_s / clean_s.max(1e-12),
        recovery_s: killed.recovery.recovery_time().as_secs_f64(),
        blocks_lost: killed.recovery.blocks_lost_cached + killed.recovery.blocks_lost_durable,
        recompute_tasks: killed.recovery.recompute_tasks,
        recompute_mib: killed.recovery.recompute_bytes as f64 / (1024.0 * 1024.0),
        ineffective_hits: killed.ineffective_hits(),
        effective_hit_ratio: killed.effective_hit_ratio(),
    }
}

fn main() {
    let quick = std::env::var("RECOVERY_BENCH_QUICK").is_ok();
    let (tenants, blocks, block_len) =
        if quick { (6u32, 12u32, 4096usize) } else { (10, 50, 65536) };

    println!(
        "recovery: multi_tenant_zip(t={tenants}, b={blocks}), kill worker 1 at 50% dispatches\n"
    );
    println!(
        "| policy | clean (s) | kill (s) | slowdown | recovery (s) | blocks lost | \
         recompute | recompute MiB | ineffective hits | eff ratio |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|");
    let rows: Vec<Row> = [PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc]
        .into_iter()
        .map(|p| {
            let r = run(p, tenants, blocks, block_len);
            println!(
                "| {} | {:.3} | {:.3} | {:.2}x | {:.3} | {} | {} | {:.1} | {} | {:.3} |",
                r.policy,
                r.clean_s,
                r.kill_s,
                r.slowdown,
                r.recovery_s,
                r.blocks_lost,
                r.recompute_tasks,
                r.recompute_mib,
                r.ineffective_hits,
                r.effective_hit_ratio
            );
            r
        })
        .collect();

    // Seeded kill + restart smoke: the worker rejoins cold mid-job, its
    // metadata is re-seeded, and the job still completes with only the
    // minimal closure recomputed.
    let restart_smoke = {
        let w = workload::multi_tenant_zip(tenants, blocks, block_len);
        let total = w.task_count() as u64;
        let workers = 4u32;
        let cache_blocks = ((tenants * blocks * 2) as u64 / 3 / workers as u64).max(2);
        let mut rcfg = cfg(PolicyKind::Lerc, workers, cache_blocks, block_len);
        rcfg.failures = FailurePlan::seeded(17, workers, total).with_restart(total / 4);
        let r = Simulator::from_engine_config(rcfg).run_workload(&w).expect("restart run");
        assert_eq!(r.recovery.workers_killed, 1, "seeded kill fired");
        assert_eq!(r.recovery.workers_restarted, 1, "worker rejoined");
        assert_eq!(r.tasks_run, total + r.recovery.recompute_tasks);
        println!(
            "\nrestart smoke (LERC, seeded): killed 1, restarted 1, \
             recomputed {} tasks, makespan {:.3}s",
            r.recovery.recompute_tasks,
            r.compute_makespan.as_secs_f64()
        );
        r
    };

    // JSON first, asserts after — a failing run still leaves its data
    // behind for diagnosis (CI uploads the artifact even on failure).
    // `effective_ratio_lerc_minus_lru` is the headline scalar the
    // manifest-driven CI guard (tools/bench_guard.py) tracks: the sim is
    // deterministic, so any drift is a real behavior change.
    let eff_gain = {
        let at = |p: &str| rows.iter().find(|r| r.policy == p).expect("row present");
        at("LERC").effective_hit_ratio - at("LRU").effective_hit_ratio
    };
    let mut json = String::from("{\n  \"bench\": \"recovery\",\n");
    let _ = writeln!(json, "  \"tenants\": {tenants},");
    let _ = writeln!(json, "  \"blocks_per_file\": {blocks},");
    let _ = writeln!(json, "  \"effective_ratio_lerc_minus_lru\": {eff_gain:.6},");
    let _ = writeln!(json, "  \"kill\": {{\"worker\": 1, \"at_dispatch_fraction\": 0.5}},");
    let _ = writeln!(
        json,
        "  \"restart_smoke\": {{\"workers_killed\": {}, \"workers_restarted\": {}, \
         \"recompute_tasks\": {}, \"makespan_s\": {:.6}}},",
        restart_smoke.recovery.workers_killed,
        restart_smoke.recovery.workers_restarted,
        restart_smoke.recovery.recompute_tasks,
        restart_smoke.compute_makespan.as_secs_f64()
    );
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"policy\": \"{}\", \"clean_s\": {:.6}, \"kill_s\": {:.6}, \
             \"slowdown\": {:.4}, \"recovery_s\": {:.6}, \"blocks_lost\": {}, \
             \"recompute_tasks\": {}, \"recompute_mib\": {:.3}, \
             \"ineffective_hits\": {}, \"effective_hit_ratio\": {:.6}}}",
            r.policy,
            r.clean_s,
            r.kill_s,
            r.slowdown,
            r.recovery_s,
            r.blocks_lost,
            r.recompute_tasks,
            r.recompute_mib,
            r.ineffective_hits,
            r.effective_hit_ratio
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_recovery.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\n(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // The acceptance claim this bench exists to demonstrate: LERC
    // recovers from the kill wasting fewer memory hits than LRU, and no
    // worse an effective ratio. Deterministic simulator — no flake room.
    let at = |p: &str| rows.iter().find(|r| r.policy == p).expect("row present");
    let (lru, lerc) = (at("LRU"), at("LERC"));
    assert!(
        lerc.ineffective_hits < lru.ineffective_hits,
        "LERC ineffective hits {} must undercut LRU {}",
        lerc.ineffective_hits,
        lru.ineffective_hits
    );
    assert!(lerc.effective_hit_ratio >= lru.effective_hit_ratio);

    println!("\nrecovery done");
}
