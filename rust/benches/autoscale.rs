//! Autoscale bench (ISSUE-9): job-completion time of a bursty Poisson
//! multi-job fleet under `TopologyPlan::Auto` vs a fixed fleet at the
//! same peak memory budget.
//!
//! The fixed fleet keeps `min_workers` workers for the whole run and is
//! granted the autoscaler's entire peak cache budget up front
//! (`max_workers x per-worker cache`, concentrated on fewer workers).
//! The elastic fleet starts at `min_workers` with the per-worker slice
//! and earns the rest by joining workers when bursts deepen the ready
//! queue — warm-migrating cached groups to each newcomer. The
//! acceptance claim, asserted below on the deterministic simulator: at
//! equal peak memory, elasticity buys compute parallelism that the
//! concentrated fixed fleet cannot, so the autoscaled mean JCT is no
//! worse than the fixed fleet's.
//!
//! Emits `BENCH_autoscale.json` (path overridable via `BENCH_OUT`),
//! guarded in CI by `tools/bench_guard.py` via the baselines manifest.
//! Reduced configuration for CI smoke runs: `AUTOSCALE_BENCH_QUICK=1`.

use lerc_engine::Engine;
use lerc_engine::common::config::{DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::metrics::FleetReport;
use lerc_engine::recovery::{AutoscaleConfig, TopologyPlan};
use lerc_engine::sim::Simulator;
use lerc_engine::workload;
use std::fmt::Write as _;
use std::time::Duration;

const MIN_WORKERS: u32 = 2;
const MAX_WORKERS: u32 = 6;
const BLOCK_LEN: usize = 4096;

fn base_cfg(workers: u32, cache_blocks: u64, plan: TopologyPlan) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(PolicyKind::Lerc)
        // Modeled disk (throttled): cache misses cost modeled time, so
        // the JCT comparison reflects cache placement, not just CPU.
        .disk(DiskConfig {
            bandwidth_bytes_per_sec: 500 * 1024 * 1024,
            seek_latency: Duration::from_micros(200),
            unthrottled: false,
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .topology(plan)
        .build()
        .expect("valid config")
}

struct Row {
    mode: &'static str,
    workers_start: u32,
    cache_blocks_per_worker: u64,
    mean_jct_s: f64,
    max_jct_s: f64,
    makespan_s: f64,
    workers_joined: u64,
    workers_retired: u64,
    blocks_migrated: u64,
    groups_migrated: u64,
    migration_bytes: u64,
    tasks: u64,
}

fn row(mode: &'static str, workers: u32, cache: u64, fleet: &FleetReport) -> Row {
    Row {
        mode,
        workers_start: workers,
        cache_blocks_per_worker: cache,
        mean_jct_s: fleet.mean_jct().as_secs_f64(),
        max_jct_s: fleet.max_jct().as_secs_f64(),
        makespan_s: fleet.aggregate.makespan.as_secs_f64(),
        workers_joined: fleet.aggregate.scale.workers_joined,
        workers_retired: fleet.aggregate.scale.workers_retired,
        blocks_migrated: fleet.aggregate.scale.blocks_migrated,
        groups_migrated: fleet.aggregate.scale.groups_migrated,
        migration_bytes: fleet.aggregate.scale.migration_bytes,
        tasks: fleet.aggregate.tasks_run,
    }
}

fn main() {
    let quick = std::env::var("AUTOSCALE_BENCH_QUICK").is_ok();
    let (jobs, blocks_per_file, mean_gap) =
        if quick { (4u32, 8u32, 8.0f64) } else { (8, 16, 12.0) };
    let seed = 7u64;
    let queue = workload::multijob_poisson(jobs, blocks_per_file, BLOCK_LEN, mean_gap, seed);
    let total = queue.task_count() as u64;

    // Per-worker cache slice at the elastic fleet's scale; the fixed
    // fleet concentrates the same PEAK budget on min_workers.
    let slice: u64 = (blocks_per_file as u64 / 2).max(4);
    let fixed_cache = slice * MAX_WORKERS as u64 / MIN_WORKERS as u64;

    println!(
        "autoscale: {jobs} Poisson jobs ({blocks_per_file} blocks/file, mean gap \
         {mean_gap} dispatches), fixed {MIN_WORKERS}w x {fixed_cache} blocks vs \
         elastic {MIN_WORKERS}..{MAX_WORKERS}w x {slice} blocks\n"
    );

    let fixed_fleet = Engine::run(
        &Simulator::from_engine_config(base_cfg(MIN_WORKERS, fixed_cache, TopologyPlan::none())),
        &queue,
    )
    .expect("fixed run");
    let auto_plan = TopologyPlan::autoscale(AutoscaleConfig {
        min_workers: MIN_WORKERS,
        max_workers: MAX_WORKERS,
        check_every: 8,
        scale_up_ready: 2,
        scale_down_ready: 0,
        mem_high: 0.85,
        mem_low: 0.0,
    });
    let auto_fleet = Engine::run(
        &Simulator::from_engine_config(base_cfg(MIN_WORKERS, slice, auto_plan)),
        &queue,
    )
    .expect("autoscale run");

    let rows = [
        row("fixed", MIN_WORKERS, fixed_cache, &fixed_fleet),
        row("autoscale", MIN_WORKERS, slice, &auto_fleet),
    ];
    println!("| mode | start w | cache/w | mean JCT (s) | max JCT (s) | makespan (s) | joined | migrated blocks |");
    println!("|---|---|---|---|---|---|---|---|");
    for r in &rows {
        println!(
            "| {} | {} | {} | {:.3} | {:.3} | {:.3} | {} | {} |",
            r.mode,
            r.workers_start,
            r.cache_blocks_per_worker,
            r.mean_jct_s,
            r.max_jct_s,
            r.makespan_s,
            r.workers_joined,
            r.blocks_migrated
        );
    }
    let (fixed, auto) = (&rows[0], &rows[1]);
    let speedup = fixed.mean_jct_s / auto.mean_jct_s.max(f64::EPSILON);
    println!(
        "\nmean JCT: fixed {:.3}s vs autoscale {:.3}s (speedup {speedup:.3}x, \
         {} joins, {} groups moved whole)",
        fixed.mean_jct_s, auto.mean_jct_s, auto.workers_joined, auto.groups_migrated
    );

    // JSON first, asserts after — a failing run still leaves its data
    // behind for diagnosis (CI uploads the artifact even on failure).
    let mut json = String::from("{\n  \"bench\": \"autoscale\",\n");
    let _ = writeln!(json, "  \"jobs\": {jobs},");
    let _ = writeln!(json, "  \"blocks_per_file\": {blocks_per_file},");
    let _ = writeln!(json, "  \"mean_gap\": {mean_gap},");
    let _ = writeln!(json, "  \"mean_jct_speedup\": {speedup:.6},");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"mode\": \"{}\", \"workers_start\": {}, \"cache_blocks_per_worker\": {}, \
             \"mean_jct_s\": {:.6}, \"max_jct_s\": {:.6}, \"makespan_s\": {:.6}, \
             \"workers_joined\": {}, \"workers_retired\": {}, \"blocks_migrated\": {}, \
             \"groups_migrated\": {}, \"migration_bytes\": {}, \"tasks\": {}}}",
            r.mode,
            r.workers_start,
            r.cache_blocks_per_worker,
            r.mean_jct_s,
            r.max_jct_s,
            r.makespan_s,
            r.workers_joined,
            r.workers_retired,
            r.blocks_migrated,
            r.groups_migrated,
            r.migration_bytes,
            r.tasks
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_autoscale.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // Sanity: both fleets run every task (autoscale may add lineage
    // recomputes on top; the workload's own tasks are all there).
    assert!(fixed.tasks >= total && auto.tasks >= total, "tasks lost");
    // A bursty queue on a two-worker fleet must actually trip the
    // scale-up thresholds — otherwise the JCT claim below is vacuous.
    assert!(
        auto.workers_joined >= 1,
        "bursty fleet never scaled up (joined {})",
        auto.workers_joined
    );
    // The ISSUE-9 acceptance claim, on the deterministic simulator — no
    // flake room: at equal peak memory, the elastic fleet's mean JCT is
    // no worse than the concentrated fixed fleet's.
    assert!(
        auto.mean_jct_s <= fixed.mean_jct_s,
        "autoscale mean JCT {:.4}s must not exceed fixed {:.4}s at equal peak memory",
        auto.mean_jct_s,
        fixed.mean_jct_s
    );

    println!("\nautoscale done");
}
