//! Micro-benchmarks of the eviction hot path: policy decision latency at
//! cache sizes up to 100k blocks (the §Perf L3 target: < 1 µs victim
//! selection at 100k blocks).

use lerc_engine::block::manager::BlockManager;
use lerc_engine::cache::policy::{new_policy, PolicyEvent};
use lerc_engine::common::config::PolicyKind;
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::harness::Bencher;
use lerc_engine::common::fxhash::FxHashSet;
use std::sync::Arc;
use std::time::Duration;

fn b(i: u32) -> BlockId {
    BlockId::new(DatasetId(i / 100_000), i % 100_000)
}

fn main() {
    let mut bench = Bencher::new().with_target(Duration::from_millis(300));
    let none = FxHashSet::default();

    for n in [1_000u32, 100_000] {
        for kind in PolicyKind::ALL {
            // Pre-populate a policy with n blocks (scores staggered).
            let mut p = new_policy(kind);
            for i in 0..n {
                p.on_event(PolicyEvent::Insert {
                    block: b(i),
                    tick: i as u64,
                });
                if kind.dag_aware() {
                    p.on_event(PolicyEvent::RefCount {
                        block: b(i),
                        count: i % 7,
                    });
                }
                if kind.peer_aware() {
                    p.on_event(PolicyEvent::EffectiveCount {
                        block: b(i),
                        count: i % 3,
                    });
                }
            }
            let mut tick = n as u64;
            // Steady-state churn: victim + remove + insert (the eviction
            // loop's exact sequence).
            bench.bench(&format!("evict_reinsert/{}/{}", kind.name(), n), || {
                let v = p.victim(&none).expect("non-empty");
                p.on_event(PolicyEvent::Remove { block: v });
                tick += 1;
                p.on_event(PolicyEvent::Insert { block: v, tick });
            });
        }
    }

    // Access path (hit bookkeeping) at 100k blocks.
    for kind in [PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc] {
        let mut p = new_policy(kind);
        for i in 0..100_000u32 {
            p.on_event(PolicyEvent::Insert {
                block: b(i),
                tick: i as u64,
            });
        }
        let mut tick = 100_000u64;
        let mut i = 0u32;
        bench.bench(&format!("access/{}/100000", kind.name()), || {
            tick += 1;
            i = (i + 7919) % 100_000;
            p.on_event(PolicyEvent::Access {
                block: b(i),
                tick,
            });
        });
    }

    // Whole block-manager insert+evict cycle (store + policy together).
    for kind in [PolicyKind::Lru, PolicyKind::Lerc] {
        let cap_blocks = 10_000u64;
        let payload_words = 64usize;
        let mut bm = BlockManager::new(cap_blocks * (payload_words as u64 * 4), kind);
        let payload: lerc_engine::cache::store::BlockData = Arc::from(vec![0.5f32; payload_words]);
        for i in 0..cap_blocks as u32 {
            bm.insert(b(i), payload.clone());
        }
        let mut i = cap_blocks as u32;
        bench.bench(&format!("block_manager_churn/{}/10000", kind.name()), || {
            bm.insert(b(i), payload.clone());
            i += 1;
        });
    }

    println!("\npolicy_micro done ({} benchmarks)", bench.results().len());
}
