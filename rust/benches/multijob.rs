//! Multi-job bench: online job queues (2/4/8 concurrent zip tenants,
//! 0% vs 50% shared input) on the deterministic simulator, LERC vs LRU.
//!
//! Per cell it reports the aggregate effective cache hit ratio (Def. 1
//! over the whole fleet) and per-job JCT statistics (admission → last
//! task, modeled time). The acceptance claim — asserted below — is the
//! ISSUE-4 criterion: with 2 jobs sharing 50% of their input, LERC's
//! aggregate effective hit ratio beats LRU's (cross-job effective
//! reference counting keeps the shared blocks' groups whole; LRU's
//! keys-before-values arrival order wastes them).
//!
//! Emits `BENCH_multijob.json` (path overridable via `BENCH_OUT`),
//! guarded in CI by `tools/bench_guard.py` via the baselines manifest.
//! Reduced configuration for CI smoke runs: `MULTIJOB_BENCH_QUICK=1`.

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind};
use lerc_engine::metrics::FleetReport;
use lerc_engine::sim::Simulator;
use lerc_engine::workload;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct Row {
    policy: &'static str,
    jobs: u32,
    shared_pct: u32,
    agg_eff_ratio: f64,
    agg_hit_ratio: f64,
    mean_jct_s: f64,
    max_jct_s: f64,
    makespan_s: f64,
    tasks: u64,
}

fn run_cell(policy: PolicyKind, jobs: u32, shared: bool, blocks: u32) -> Row {
    let block_len = 4096usize;
    let workers = 4u32;
    // Arrival gap of half a job's task count: the queue genuinely
    // overlaps — later jobs land while earlier ones still compute.
    let queue = workload::multijob_zip_shared(jobs, blocks, block_len, shared, blocks as u64 / 2);
    // Cache ~1/3 of the DISTINCT input blocks (shared blocks counted
    // once): the paper's pressure zone.
    let distinct = if shared {
        (blocks + jobs * blocks) as u64
    } else {
        (2 * jobs * blocks) as u64
    };
    let cache_blocks = (distinct / 3 / workers as u64).max(2);
    let cfg = EngineConfig::builder()
        .num_workers(workers)
        .block_len(block_len)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .build()
        .expect("valid config");
    let fleet: FleetReport =
        Engine::run(&Simulator::from_engine_config(cfg), &queue).expect("bench run");
    assert_eq!(
        fleet.aggregate.tasks_run,
        queue.task_count() as u64,
        "every job's every task ran"
    );
    Row {
        policy: policy.name(),
        jobs,
        shared_pct: if shared { 50 } else { 0 },
        agg_eff_ratio: fleet.aggregate_effective_hit_ratio(),
        agg_hit_ratio: fleet.aggregate.hit_ratio(),
        mean_jct_s: fleet.mean_jct().as_secs_f64(),
        max_jct_s: fleet.max_jct().as_secs_f64(),
        makespan_s: fleet.aggregate.makespan.as_secs_f64(),
        tasks: fleet.aggregate.tasks_run,
    }
}

fn main() {
    let quick = std::env::var("MULTIJOB_BENCH_QUICK").is_ok();
    let (job_counts, blocks): (&[u32], u32) =
        if quick { (&[2, 4], 12) } else { (&[2, 4, 8], 24) };

    println!("multijob: online zip queues, {blocks} blocks/file, LERC vs LRU\n");
    println!("| policy | jobs | shared | agg eff ratio | agg hit ratio | mean JCT (s) | max JCT (s) | makespan (s) |");
    println!("|---|---|---|---|---|---|---|---|");
    let mut rows: Vec<Row> = Vec::new();
    for &jobs in job_counts {
        for shared in [false, true] {
            for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
                let row = run_cell(policy, jobs, shared, blocks);
                println!(
                    "| {} | {} | {}% | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} |",
                    row.policy,
                    row.jobs,
                    row.shared_pct,
                    row.agg_eff_ratio,
                    row.agg_hit_ratio,
                    row.mean_jct_s,
                    row.max_jct_s,
                    row.makespan_s
                );
                rows.push(row);
            }
        }
    }

    let at = |policy: &str, jobs: u32, shared_pct: u32| {
        rows.iter()
            .find(|r| r.policy == policy && r.jobs == jobs && r.shared_pct == shared_pct)
            .expect("row present")
    };
    let lerc2 = at("LERC", 2, 50);
    let lru2 = at("LRU", 2, 50);
    let gain = lerc2.agg_eff_ratio - lru2.agg_eff_ratio;
    println!(
        "\n2 jobs / 50% shared: LERC agg eff ratio {:.3} vs LRU {:.3} (gain {gain:+.3})",
        lerc2.agg_eff_ratio, lru2.agg_eff_ratio
    );

    // JSON first, asserts after — a failing run still leaves its data
    // behind for diagnosis (CI uploads the artifact even on failure).
    let mut json = String::from("{\n  \"bench\": \"multijob\",\n");
    let _ = writeln!(json, "  \"blocks_per_file\": {blocks},");
    let _ = writeln!(json, "  \"eff_ratio_gain_2jobs_50shared\": {gain:.6},");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"policy\": \"{}\", \"jobs\": {}, \"shared_pct\": {}, \
             \"agg_eff_ratio\": {:.6}, \"agg_hit_ratio\": {:.6}, \"mean_jct_s\": {:.6}, \
             \"max_jct_s\": {:.6}, \"makespan_s\": {:.6}, \"tasks\": {}}}",
            r.policy,
            r.jobs,
            r.shared_pct,
            r.agg_eff_ratio,
            r.agg_hit_ratio,
            r.mean_jct_s,
            r.max_jct_s,
            r.makespan_s,
            r.tasks
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_multijob.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // The ISSUE-4 acceptance claim, on the deterministic simulator — no
    // flake room: cross-job effective reference counting must lift the
    // aggregate effective hit ratio over LRU when jobs share input.
    assert!(
        lerc2.agg_eff_ratio > lru2.agg_eff_ratio,
        "LERC agg effective ratio {:.4} must beat LRU {:.4} at 2 jobs / 50% shared",
        lerc2.agg_eff_ratio,
        lru2.agg_eff_ratio
    );
    // Sanity on the sweep: LERC never loses to LRU on effective ratio
    // in any cell.
    for &jobs in job_counts {
        for shared_pct in [0u32, 50] {
            let lerc = at("LERC", jobs, shared_pct);
            let lru = at("LRU", jobs, shared_pct);
            assert!(
                lerc.agg_eff_ratio >= lru.agg_eff_ratio,
                "LERC below LRU at jobs={jobs} shared={shared_pct}%"
            );
        }
    }

    println!("\nmultijob done");
}
