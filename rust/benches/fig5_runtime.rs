//! Paper Fig 5 + Fig 6 + Fig 7 as a bench target: the full cache-size ×
//! policy sweep at the paper's geometry (one simulator run yields all
//! three series: runtime, hit ratio, effective hit ratio).

use lerc_engine::harness::Bencher;
use lerc_engine::harness::experiments::{fig5_6_7_sweep, ExpOptions};
use lerc_engine::metrics::report::markdown_table;
use std::time::Duration;

fn main() {
    let mut bench = Bencher::new().with_target(Duration::from_millis(300));

    let opts = ExpOptions::default(); // 10 tenants × 2 × 50 × 256 KiB
    let rows = bench.bench_once("fig5_6_7/sweep_paper_geometry", || {
        fig5_6_7_sweep(&opts).expect("sweep")
    });
    println!("\n{}", markdown_table(&rows));

    // Paper-shape assertions at every cache size.
    for frac in &opts.fractions {
        let get = |p: &str| {
            rows.iter()
                .find(|r| (r.cache_fraction - frac).abs() < 1e-3 && r.policy == p)
                .unwrap()
        };
        let (lru, lrc, lerc) = (get("LRU"), get("LRC"), get("LERC"));
        assert!(lerc.makespan_s <= lrc.makespan_s + 1e-9, "f={frac}");
        assert!(lrc.makespan_s <= lru.makespan_s + 1e-9, "f={frac}");
        assert!(
            lerc.effective_hit_ratio >= lrc.effective_hit_ratio - 1e-9,
            "f={frac}"
        );
        assert!(lru.effective_hit_ratio < 0.05, "LRU eff ~0 (f={frac})");
        // Fig 6: LRC's plain hit ratio is at least LERC's.
        assert!(lrc.hit_ratio >= lerc.hit_ratio - 1e-9, "f={frac}");
    }

    // Headline: LERC vs LRU at the 2/3-cache point (paper: -37.0%).
    let at = |p: &str| {
        rows.iter()
            .find(|r| (r.cache_fraction - 0.66).abs() < 0.02 && r.policy == p)
            .unwrap()
            .makespan_s
    };
    let gain_lru = 100.0 * (1.0 - at("LERC") / at("LRU"));
    let gain_lrc = 100.0 * (1.0 - at("LERC") / at("LRC"));
    println!(
        "headline @2/3 cache: LERC vs LRU -{gain_lru:.1}% (paper -37.0%), vs LRC -{gain_lrc:.1}% (paper -18.6%)"
    );
    assert!(gain_lru > 20.0, "LERC-vs-LRU gain collapsed: {gain_lru}");

    // Timing: single sweep point on the simulator.
    let single = ExpOptions {
        fractions: vec![0.5],
        ..Default::default()
    };
    bench.bench_once("fig5/single_point", || {
        fig5_6_7_sweep(&single).expect("sweep")
    });

    println!("\nfig5_runtime done");
}
