//! Paper §III-A strawman ablation: sticky whole-group eviction vs LERC
//! vs LRC on shared-input workloads.
//!
//! The paper's argument: a block shared by several tasks should NOT be
//! surrendered just because one of its peer-groups broke — caching it may
//! still benefit another task. The showcase point is a 2-consumer share
//! with cache sized to hold the shared dataset plus exactly one partner
//! dataset (fraction ≈ 2/3): LERC keeps the shared blocks and serves the
//! surviving consumer fully; sticky cascades the shared blocks out.
//!
//! The full pressure sweep is also reported: at harsher pressures,
//! aggressive whole-group eviction can actually win by concentrating
//! cache on fewer intact groups — a trade-off the paper does not explore
//! (see EXPERIMENTS.md §Ablations).

use lerc_engine::harness::Bencher;
use lerc_engine::harness::experiments::ablation_sticky;
use std::time::Duration;

fn main() {
    let mut bench = Bencher::new().with_target(Duration::from_millis(300));

    // The paper's exact §III-A argument as a single decision: a block
    // shared by three tasks, one group broken, two complete.
    let decision = bench.bench_once("ablation_sticky/single_decision", || {
        lerc_engine::harness::experiments::sticky_single_decision()
    });
    println!("\n§III-A single decision (6 task accesses):");
    for (policy, eff) in &decision {
        println!("  {policy}: {eff} effective hits");
    }
    let lerc_eff = decision.iter().find(|(p, _)| p == "LERC").unwrap().1;
    let sticky_eff = decision.iter().find(|(p, _)| p == "Sticky").unwrap().1;
    assert!(
        lerc_eff > sticky_eff,
        "LERC must retain the shared block's remaining effective references \
         (LERC {lerc_eff} vs Sticky {sticky_eff})"
    );

    // Full pressure sweep (reported, not asserted — the trade-off is
    // workload-dependent and documented in EXPERIMENTS.md).
    println!("\npressure sweep (4 consumers):");
    println!("| fraction | LERC eff | Sticky eff | LERC t(s) | Sticky t(s) |");
    println!("|---|---|---|---|---|");
    for frac in [0.3, 0.4, 0.5, 0.6, 0.7, 0.8] {
        let r = bench.bench_once(&format!("ablation_sticky/4c_f{frac}"), || {
            ablation_sticky(4, 24, 65536, frac).expect("ablation")
        });
        println!(
            "| {:.1} | {:.3} | {:.3} | {:.3} | {:.3} |",
            frac,
            r[0].effective_hit_ratio(),
            r[1].effective_hit_ratio(),
            r[0].compute_makespan.as_secs_f64(),
            r[1].compute_makespan.as_secs_f64()
        );
    }

    println!("\nablation_sticky done");
}
