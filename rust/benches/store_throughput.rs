//! Multi-threaded throughput bench for the sharded block store: aggregate
//! get/insert ops/sec at 1, 2 and 4 worker threads hammering ONE shared
//! [`ShardedStore`], with 1 shard (the old monolithic geometry) vs many.
//!
//! Emits `BENCH_store_throughput.json` (path overridable via `BENCH_OUT`)
//! so the perf trajectory is machine-readable run over run. Reduced
//! configurations for CI smoke runs: set `STORE_BENCH_QUICK=1` or
//! `STORE_BENCH_OPS=<n>`.
//!
//! The headline figure is `speedup_1_to_4`: aggregate ops/sec going from
//! 1 to 4 threads on the many-shard store. On a ≥4-core machine this
//! should clear 2× (the single-shard row is the contention baseline that
//! shows why the striping exists).

use lerc_engine::cache::sharded::ShardedStore;
use lerc_engine::common::config::PolicyKind;
use lerc_engine::common::ids::{BlockId, DatasetId, GroupId};
use lerc_engine::common::rng::SplitMix64;
use std::fmt::Write as _;
use std::sync::{Arc, Barrier};
use std::time::Instant;

const PAYLOAD_WORDS: usize = 64; // 256 B blocks: the lock, not memcpy, dominates
const KEYSPACE: u32 = 16_384;

#[derive(Debug, Clone)]
struct Row {
    threads: usize,
    shards: usize,
    total_ops: u64,
    secs: f64,
    ops_per_sec: f64,
}

fn bench_case(threads: usize, shards: usize, ops_per_thread: u64) -> Row {
    // Capacity for half the keyspace: steady-state inserts evict.
    let capacity = (KEYSPACE as u64 / 2) * (PAYLOAD_WORDS as u64) * 4;
    let store = Arc::new(ShardedStore::new(capacity, PolicyKind::Lerc, shards));
    let payload = Arc::new(vec![0.5f32; PAYLOAD_WORDS]);

    // Pre-populate from a single thread.
    let mut rng = SplitMix64::new(7);
    for _ in 0..KEYSPACE {
        let b = BlockId::new(DatasetId(0), rng.next_below(KEYSPACE as u64) as u32);
        store.insert(b, payload.clone());
    }

    let barrier = Arc::new(Barrier::new(threads + 1));
    let mut joins = Vec::with_capacity(threads);
    for t in 0..threads {
        let store = store.clone();
        let payload = payload.clone();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0xBE2C ^ t as u64);
            barrier.wait();
            for i in 0..ops_per_thread {
                let r = rng.next_u64();
                let b = BlockId::new(DatasetId(0), (r >> 32) as u32 % KEYSPACE);
                match r % 16 {
                    // ~6% inserts: steady eviction churn.
                    0 => {
                        store.insert(b, payload.clone());
                    }
                    // ~6% group pin/unpin cycles: the cross-shard intent path.
                    1 => {
                        let gid = GroupId(((t as u64) << 48) | i);
                        let peer = BlockId::new(DatasetId(0), (r >> 16) as u32 % KEYSPACE);
                        if store.pin_group(gid, &[b, peer]) {
                            store.unpin_group(gid);
                        }
                    }
                    // ~88% reads: the remote/local hit path.
                    _ => {
                        let _ = store.get(b);
                    }
                }
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for j in joins {
        j.join().expect("bench worker panicked");
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    store.check_invariants().expect("store invariants");
    assert_eq!(store.pinned_group_count(), 0, "leaked group pins");

    let total_ops = ops_per_thread * threads as u64;
    Row {
        threads,
        shards,
        total_ops,
        secs,
        ops_per_sec: total_ops as f64 / secs,
    }
}

fn main() {
    let quick = std::env::var("STORE_BENCH_QUICK").is_ok();
    let ops_per_thread: u64 = std::env::var("STORE_BENCH_OPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 20_000 } else { 400_000 });

    println!("store_throughput: {ops_per_thread} ops/thread, keyspace {KEYSPACE}\n");
    println!("| threads | shards | total ops | secs | ops/sec |");
    println!("|---|---|---|---|---|");
    let mut rows: Vec<Row> = Vec::new();
    for &shards in &[1usize, 32] {
        for &threads in &[1usize, 2, 4] {
            let row = bench_case(threads, shards, ops_per_thread);
            println!(
                "| {} | {} | {} | {:.3} | {:.0} |",
                row.threads, row.shards, row.total_ops, row.secs, row.ops_per_sec
            );
            rows.push(row);
        }
    }

    let at = |threads: usize, shards: usize| {
        rows.iter()
            .find(|r| r.threads == threads && r.shards == shards)
            .expect("row present")
            .ops_per_sec
    };
    let speedup_sharded = at(4, 32) / at(1, 32);
    let speedup_monolithic = at(4, 1) / at(1, 1);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "\n1->4-thread scaling: sharded (32) {speedup_sharded:.2}x, \
         monolithic (1) {speedup_monolithic:.2}x ({cores} cores)"
    );
    if cores >= 4 && speedup_sharded < 2.0 && !quick {
        eprintln!("WARNING: sharded store scaled < 2x on a {cores}-core machine");
    }

    // Hand-rolled JSON (no serde in the offline build).
    let mut json = String::from("{\n  \"bench\": \"store_throughput\",\n");
    let _ = writeln!(json, "  \"ops_per_thread\": {ops_per_thread},");
    let _ = writeln!(json, "  \"cores\": {cores},");
    let _ = writeln!(json, "  \"speedup_1_to_4_sharded\": {speedup_sharded:.4},");
    let _ = writeln!(json, "  \"speedup_1_to_4_monolithic\": {speedup_monolithic:.4},");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"threads\": {}, \"shards\": {}, \"total_ops\": {}, \"secs\": {:.6}, \"ops_per_sec\": {:.1}}}",
            r.threads, r.shards, r.total_ops, r.secs, r.ops_per_sec
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_store_throughput.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    println!("\nstore_throughput done");
}
