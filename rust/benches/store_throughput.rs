//! Multi-threaded throughput bench for the sharded block store: aggregate
//! get/insert ops/sec at 1, 2 and 4 worker threads hammering ONE shared
//! [`ShardedStore`], with 1 shard (the old monolithic geometry) vs many —
//! plus a read-heavy mix (95% gets) at 8 and 16 threads comparing the
//! Locked and Optimistic read paths (DESIGN.md §7).
//!
//! Emits `BENCH_store_throughput.json` (path overridable via `BENCH_OUT`)
//! so the perf trajectory is machine-readable run over run. Reduced
//! configurations for CI smoke runs: set `STORE_BENCH_QUICK=1` or
//! `STORE_BENCH_OPS=<n>`.
//!
//! Headline figures:
//! * `speedup_1_to_4_sharded`: aggregate ops/sec going from 1 to 4
//!   threads on the many-shard store (the striping payoff; single-shard
//!   row is the contention baseline).
//! * `ops_per_sec_read_heavy_16t`: the Optimistic read path at 16
//!   threads on the read-heavy mix — the ratcheted guard metric.
//! * `read_heavy_speedup_16t`: Optimistic vs Locked at 16 threads; must
//!   clear 2× on a ≥8-core machine (asserted below, warning otherwise).

use lerc_engine::cache::sharded::{ShardedStore, DEFAULT_TOUCH_BUFFER};
use lerc_engine::cache::store::BlockData;
use lerc_engine::common::config::{PolicyKind, StoreReadPath};
use lerc_engine::common::ids::{BlockId, DatasetId, GroupId};
use lerc_engine::common::rng::SplitMix64;
use std::fmt::Write as _;
use std::sync::{Arc, Barrier};
use std::time::Instant;

const PAYLOAD_WORDS: usize = 64; // 256 B blocks: the lock, not memcpy, dominates
const KEYSPACE: u32 = 16_384;

#[derive(Debug, Clone)]
struct Row {
    threads: usize,
    shards: usize,
    mix: &'static str,
    path: StoreReadPath,
    total_ops: u64,
    secs: f64,
    ops_per_sec: f64,
}

fn make_store(shards: usize, path: StoreReadPath) -> Arc<ShardedStore> {
    // Capacity for half the keyspace: steady-state inserts evict.
    let capacity = (KEYSPACE as u64 / 2) * (PAYLOAD_WORDS as u64) * 4;
    let store = Arc::new(ShardedStore::with_read_path(
        capacity,
        PolicyKind::Lerc,
        shards,
        path,
        DEFAULT_TOUCH_BUFFER,
    ));
    let payload: BlockData = Arc::from(vec![0.5f32; PAYLOAD_WORDS]);

    // Pre-populate from a single thread.
    let mut rng = SplitMix64::new(7);
    for _ in 0..KEYSPACE {
        let b = BlockId::new(DatasetId(0), rng.next_below(KEYSPACE as u64) as u32);
        store.insert(b, payload.clone());
    }
    store
}

/// Run `threads` workers over `store`, each executing `body(rng_draw,
/// thread, op_index)` `ops_per_thread` times; returns elapsed seconds.
fn run_threads<F>(store: &Arc<ShardedStore>, threads: usize, ops_per_thread: u64, body: F) -> f64
where
    F: Fn(&Arc<ShardedStore>, u64, usize, u64) + Send + Sync + 'static,
{
    let body = Arc::new(body);
    let barrier = Arc::new(Barrier::new(threads + 1));
    let mut joins = Vec::with_capacity(threads);
    for t in 0..threads {
        let store = store.clone();
        let barrier = barrier.clone();
        let body = body.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0xBE2C ^ t as u64);
            barrier.wait();
            for i in 0..ops_per_thread {
                body(&store, rng.next_u64(), t, i);
            }
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for j in joins {
        j.join().expect("bench worker panicked");
    }
    t0.elapsed().as_secs_f64().max(1e-9)
}

/// The original mixed workload: ~6% inserts, ~6% group pin/unpin cycles,
/// ~88% gets. Always the Locked read path (the baseline series).
fn bench_mixed(threads: usize, shards: usize, ops_per_thread: u64) -> Row {
    let store = make_store(shards, StoreReadPath::Locked);
    let payload: BlockData = Arc::from(vec![0.5f32; PAYLOAD_WORDS]);
    let secs = run_threads(&store, threads, ops_per_thread, move |store, r, t, i| {
        let b = BlockId::new(DatasetId(0), (r >> 32) as u32 % KEYSPACE);
        match r % 16 {
            // ~6% inserts: steady eviction churn.
            0 => {
                store.insert(b, payload.clone());
            }
            // ~6% group pin/unpin cycles: the cross-shard intent path.
            1 => {
                let gid = GroupId(((t as u64) << 48) | i);
                let peer = BlockId::new(DatasetId(0), (r >> 16) as u32 % KEYSPACE);
                if store.pin_group(gid, &[b, peer]) {
                    store.unpin_group(gid);
                }
            }
            // ~88% reads: the remote/local hit path.
            _ => {
                let _ = store.get(b);
            }
        }
    });
    store.check_invariants().expect("store invariants");
    assert_eq!(store.pinned_group_count(), 0, "leaked group pins");

    let total_ops = ops_per_thread * threads as u64;
    Row {
        threads,
        shards,
        mix: "mixed",
        path: StoreReadPath::Locked,
        total_ops,
        secs,
        ops_per_sec: total_ops as f64 / secs,
    }
}

/// The read-heavy workload the Optimistic path exists for: 95% gets, 5%
/// inserts, no group cycling — the shape of a remote-fetch-dominated
/// stage serving peers.
fn bench_read_heavy(
    threads: usize,
    shards: usize,
    path: StoreReadPath,
    ops_per_thread: u64,
) -> Row {
    let store = make_store(shards, path);
    let payload: BlockData = Arc::from(vec![0.5f32; PAYLOAD_WORDS]);
    let secs = run_threads(&store, threads, ops_per_thread, move |store, r, _t, _i| {
        let b = BlockId::new(DatasetId(0), (r >> 32) as u32 % KEYSPACE);
        if r % 20 == 0 {
            store.insert(b, payload.clone());
        } else {
            let _ = store.get(b);
        }
    });
    store.flush_touches();
    store.check_invariants().expect("store invariants");

    let total_ops = ops_per_thread * threads as u64;
    Row {
        threads,
        shards,
        mix: "read_heavy",
        path,
        total_ops,
        secs,
        ops_per_sec: total_ops as f64 / secs,
    }
}

fn print_row(r: &Row) {
    println!(
        "| {} | {} | {} | {} | {} | {:.3} | {:.0} |",
        r.threads,
        r.shards,
        r.mix,
        r.path.name(),
        r.total_ops,
        r.secs,
        r.ops_per_sec
    );
}

fn main() {
    let quick = std::env::var("STORE_BENCH_QUICK").is_ok();
    let ops_per_thread: u64 = std::env::var("STORE_BENCH_OPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 20_000 } else { 400_000 });

    println!("store_throughput: {ops_per_thread} ops/thread, keyspace {KEYSPACE}\n");
    println!("| threads | shards | mix | path | total ops | secs | ops/sec |");
    println!("|---|---|---|---|---|---|---|");
    let mut rows: Vec<Row> = Vec::new();
    for &shards in &[1usize, 32] {
        for &threads in &[1usize, 2, 4] {
            let row = bench_mixed(threads, shards, ops_per_thread);
            print_row(&row);
            rows.push(row);
        }
    }
    // Read-heavy series: many shards, high thread counts, both read
    // paths. This is where the optimistic path separates from the lock.
    for &threads in &[8usize, 16] {
        for &path in &[StoreReadPath::Locked, StoreReadPath::Optimistic] {
            let row = bench_read_heavy(threads, 32, path, ops_per_thread);
            print_row(&row);
            rows.push(row);
        }
    }

    let mixed_at = |threads: usize, shards: usize| {
        rows.iter()
            .find(|r| r.mix == "mixed" && r.threads == threads && r.shards == shards)
            .expect("row present")
            .ops_per_sec
    };
    let read_heavy_at = |threads: usize, path: StoreReadPath| {
        rows.iter()
            .find(|r| r.mix == "read_heavy" && r.threads == threads && r.path == path)
            .expect("row present")
            .ops_per_sec
    };
    let speedup_sharded = mixed_at(4, 32) / mixed_at(1, 32);
    let speedup_monolithic = mixed_at(4, 1) / mixed_at(1, 1);
    let read_heavy_16t = read_heavy_at(16, StoreReadPath::Optimistic);
    let read_heavy_speedup_16t = read_heavy_16t / read_heavy_at(16, StoreReadPath::Locked);
    let read_heavy_speedup_8t =
        read_heavy_at(8, StoreReadPath::Optimistic) / read_heavy_at(8, StoreReadPath::Locked);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "\n1->4-thread scaling: sharded (32) {speedup_sharded:.2}x, \
         monolithic (1) {speedup_monolithic:.2}x ({cores} cores)"
    );
    println!(
        "read-heavy optimistic vs locked: {read_heavy_speedup_8t:.2}x at 8t, \
         {read_heavy_speedup_16t:.2}x at 16t"
    );
    if cores >= 4 && speedup_sharded < 2.0 && !quick {
        eprintln!("WARNING: sharded store scaled < 2x on a {cores}-core machine");
    }
    // Acceptance gate: on real hardware the optimistic read path must
    // beat the lock by 2x on the get-heavy mix. Quick/smoke runs and
    // small machines only warn — thread counts past the core count
    // measure the scheduler, not the store.
    if cores >= 8 && !quick {
        assert!(
            read_heavy_speedup_16t >= 2.0,
            "optimistic read path only {read_heavy_speedup_16t:.2}x vs locked \
             at 16 threads on a {cores}-core machine (need >= 2x)"
        );
    } else if read_heavy_speedup_16t < 2.0 {
        eprintln!(
            "WARNING: read-heavy optimistic speedup {read_heavy_speedup_16t:.2}x < 2x \
             (not asserted: cores={cores}, quick={quick})"
        );
    }

    // Hand-rolled JSON (no serde in the offline build).
    let mut json = String::from("{\n  \"bench\": \"store_throughput\",\n");
    let _ = writeln!(json, "  \"ops_per_thread\": {ops_per_thread},");
    let _ = writeln!(json, "  \"cores\": {cores},");
    let _ = writeln!(json, "  \"speedup_1_to_4_sharded\": {speedup_sharded:.4},");
    let _ = writeln!(json, "  \"speedup_1_to_4_monolithic\": {speedup_monolithic:.4},");
    let _ = writeln!(json, "  \"ops_per_sec_read_heavy_16t\": {read_heavy_16t:.1},");
    let _ = writeln!(json, "  \"read_heavy_speedup_16t\": {read_heavy_speedup_16t:.4},");
    let _ = writeln!(json, "  \"read_heavy_speedup_8t\": {read_heavy_speedup_8t:.4},");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"threads\": {}, \"shards\": {}, \"mix\": \"{}\", \"path\": \"{}\", \
             \"total_ops\": {}, \"secs\": {:.6}, \"ops_per_sec\": {:.1}}}",
            r.threads,
            r.shards,
            r.mix,
            r.path.name(),
            r.total_ops,
            r.secs,
            r.ops_per_sec
        );
        json.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_store_throughput.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    println!("\nstore_throughput done");
}
