//! Paper Fig 1 (toy example) as a bench target: regenerates the
//! eviction-decision table for every policy and times the decision path.

use lerc_engine::common::config::PolicyKind;
use lerc_engine::harness::Bencher;
use lerc_engine::harness::experiments::{print_toy_table, toy_fig1_table};
use std::time::Duration;

fn main() {
    let mut bench = Bencher::new().with_target(Duration::from_millis(200));

    let rows = bench.bench_once("toy_fig1/all_policies", || toy_fig1_table(&PolicyKind::ALL));
    println!();
    print_toy_table(&rows);

    // Verify the paper's claims hold in the bench run too.
    let lerc = rows.iter().find(|r| r.policy == "LERC").expect("LERC");
    assert_eq!(lerc.evicted, "c", "LERC must evict c (paper Fig 1)");
    assert!((lerc.effective_hit_ratio - 0.5).abs() < 1e-9);
    let lru = rows.iter().find(|r| r.policy == "LRU").expect("LRU");
    assert_eq!(lru.effective_hit_ratio, 0.0);

    bench.bench("toy_fig1/decision_only", || {
        let rows = toy_fig1_table(&[PolicyKind::Lerc]);
        assert_eq!(rows[0].evicted, "c");
    });

    println!("\ntoy_example done");
}
