//! Paper Fig 3 as a bench target: regenerates the all-or-nothing
//! staircase (hit ratio vs total task runtime as pairs complete).

use lerc_engine::harness::Bencher;
use lerc_engine::harness::experiments::{fig3_all_or_nothing, print_fig3};
use std::time::Duration;

fn main() {
    let mut bench = Bencher::new().with_target(Duration::from_millis(300));

    let rows = bench.bench_once("fig3/staircase_10_blocks", || {
        fig3_all_or_nothing(10, 65536).expect("fig3")
    });
    println!();
    print_fig3(&rows);

    // Paper shape checks: linear hit ratio, pair-sized runtime steps.
    for w in rows.windows(2) {
        assert!(w[1].hit_ratio >= w[0].hit_ratio - 1e-9);
    }
    let full = rows.last().unwrap().total_runtime;
    let empty = rows.first().unwrap().total_runtime;
    assert!(
        full < empty,
        "fully cached must beat uncached ({full:?} vs {empty:?})"
    );
    for k in (1..rows.len()).step_by(2) {
        let d = rows[k - 1].total_runtime.as_secs_f64() - rows[k].total_runtime.as_secs_f64();
        assert!(
            d.abs() < 0.02 * empty.as_secs_f64(),
            "half-pair k={k} changed runtime"
        );
    }

    // Scale check: the same experiment at 2× blocks.
    bench.bench_once("fig3/staircase_20_blocks", || {
        fig3_all_or_nothing(20, 65536).expect("fig3")
    });

    println!("\nfig3_all_or_nothing done");
}
