//! Paper §III-C as a bench target: LERC's coordination traffic across
//! cache pressures, checking the ≤1-broadcast-per-peer-group bound.

use lerc_engine::harness::Bencher;
use lerc_engine::harness::experiments::{comm_overhead, print_comm, ExpOptions};
use std::time::Duration;

fn main() {
    let mut bench = Bencher::new().with_target(Duration::from_millis(300));

    let opts = ExpOptions::default();
    let rows = bench.bench_once("comm_overhead/paper_geometry", || {
        comm_overhead(&opts).expect("comm")
    });
    println!();
    print_comm(&rows);

    for r in &rows {
        assert!(
            r.broadcasts <= r.peer_groups,
            "protocol bound violated: {} broadcasts > {} groups at f={}",
            r.broadcasts,
            r.peer_groups,
            r.cache_fraction
        );
        // Every broadcast must have been triggered by >= 1 report.
        assert!(r.eviction_reports >= r.broadcasts);
    }
    // Traffic decreases as cache pressure falls (paper §IV-B discussion).
    assert!(
        rows.last().unwrap().broadcasts <= rows.first().unwrap().broadcasts,
        "traffic should shrink with larger caches"
    );

    println!("\ncomm_overhead done");
}
