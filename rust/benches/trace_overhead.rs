//! Trace-overhead bench: the flight recorder's cost on the hot path
//! (DESIGN.md §8). Runs the paper's multi-tenant zip workload on the
//! deterministic simulator three times per sample — `TraceConfig::Off`,
//! `TraceConfig::Collect` including the drain + both exporters, and
//! Collect with the continuous telemetry sampler on (DESIGN.md §10,
//! counter tracks included in the Chrome export) — and reports both
//! wall-clock ratios against Off. The manifest guard holds each ratio
//! under a `min_delta` ceiling: tracing a run, sampler included, must
//! never cost more than 10% over running it dark.
//!
//! Emits `BENCH_trace_overhead.json` (path overridable via `BENCH_OUT`)
//! plus the trace artifacts themselves (`trace.jsonl`,
//! `trace.chrome.json`, `timeline.jsonl`; directory overridable via
//! `TRACE_OVERHEAD_DIR`) so CI can upload a Perfetto-loadable trace from
//! every run. Reduced configuration for CI smoke runs:
//! `TRACE_OVERHEAD_BENCH_QUICK=1`.

use lerc_engine::common::config::{CtrlPlane, EngineConfig, PolicyKind, TimelineConfig};
use lerc_engine::sim::Simulator;
use lerc_engine::trace::sink::{ChromeSink, JsonlSink, TraceMeta, TraceSink};
use lerc_engine::trace::{TraceConfig, DEFAULT_RING_CAPACITY};
use lerc_engine::workload;
use lerc_engine::Engine;
use std::fmt::Write as _;
use std::time::{Duration, Instant};

const WORKERS: u32 = 4;

fn cfg(input_bytes: u64, block_len: usize, trace: TraceConfig) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(WORKERS)
        // Half the input: tight enough to evict, break groups, and emit
        // ineffective-hit attributions — the expensive event mix.
        .cache_capacity_per_worker(input_bytes / 2 / WORKERS as u64)
        .block_len(block_len)
        .policy(PolicyKind::Lerc)
        .ctrl_plane(CtrlPlane::Broadcast)
        .trace(trace)
        .build()
        .expect("valid config")
}

fn main() {
    let quick = std::env::var("TRACE_OVERHEAD_BENCH_QUICK").is_ok();
    let (tenants, blocks, block_len, samples) =
        if quick { (4u32, 10u32, 4096usize, 3u32) } else { (10, 50, 16384, 5) };
    let w = workload::multi_tenant_zip(tenants, blocks, block_len);
    let input_bytes = w.input_bytes();

    println!(
        "trace_overhead: multi_tenant_zip(t={tenants}, b={blocks}, len={block_len}), \
         LERC, {WORKERS} workers, best of {samples}\n"
    );

    // Warm both paths once (allocator + page-cache effects).
    Simulator::from_engine_config(cfg(input_bytes, block_len, TraceConfig::Off))
        .run_workload(&w)
        .expect("warmup run");

    // Best-of-N wall times: min is the right statistic for a ratio of
    // two deterministic runs — it strips scheduler noise, not work.
    let mut off_best = Duration::MAX;
    for _ in 0..samples {
        let t0 = Instant::now();
        Simulator::from_engine_config(cfg(input_bytes, block_len, TraceConfig::Off))
            .run_workload(&w)
            .expect("off run");
        off_best = off_best.min(t0.elapsed());
    }

    let mut collect_best = Duration::MAX;
    let mut events = 0usize;
    let mut dropped = 0u64;
    let mut jsonl_bytes: Vec<u8> = Vec::new();
    let mut chrome_bytes: Vec<u8> = Vec::new();
    for _ in 0..samples {
        let (trace, rec) = TraceConfig::collect(DEFAULT_RING_CAPACITY);
        let t0 = Instant::now();
        Simulator::from_engine_config(cfg(input_bytes, block_len, trace))
            .run_workload(&w)
            .expect("collect run");
        let log = rec.take();
        let meta = TraceMeta {
            engine: "sim".into(),
            clock: rec.clock(),
            workers: WORKERS,
            dropped: rec.dropped(),
        };
        let mut jsink = JsonlSink::new(Vec::new());
        jsink.export(&meta, &log).expect("jsonl export");
        let mut csink = ChromeSink::new(Vec::new());
        csink.export(&meta, &log).expect("chrome export");
        collect_best = collect_best.min(t0.elapsed());
        events = log.len();
        dropped = rec.dropped();
        jsonl_bytes = jsink.into_inner();
        chrome_bytes = csink.into_inner();
    }

    // Third arm: Collect plus the telemetry sampler — the full §10
    // observability stack a `lerc analyze` run pays for. The Chrome
    // export carries the sampler's counter tracks in this arm.
    let mut sampler_best = Duration::MAX;
    let mut timeline_samples = 0usize;
    let mut timeline_bytes = String::new();
    for _ in 0..samples {
        let (trace, rec) = TraceConfig::collect(DEFAULT_RING_CAPACITY);
        let mut c = cfg(input_bytes, block_len, trace);
        c.timeline = Some(TimelineConfig::default());
        let t0 = Instant::now();
        let report = Simulator::from_engine_config(c).run_workload(&w).expect("sampler run");
        let log = rec.take();
        let meta = TraceMeta {
            engine: "sim".into(),
            clock: rec.clock(),
            workers: WORKERS,
            dropped: rec.dropped(),
        };
        let mut jsink = JsonlSink::new(Vec::new());
        jsink.export(&meta, &log).expect("jsonl export");
        let mut csink = ChromeSink::new(Vec::new()).with_timeline(&report.timeline);
        csink.export(&meta, &log).expect("chrome export");
        let tl = report.timeline.to_jsonl();
        sampler_best = sampler_best.min(t0.elapsed());
        timeline_samples = report.timeline.len();
        timeline_bytes = tl;
        chrome_bytes = csink.into_inner();
    }

    let overhead_ratio = collect_best.as_secs_f64() / off_best.as_secs_f64().max(1e-9);
    let sampler_ratio = sampler_best.as_secs_f64() / off_best.as_secs_f64().max(1e-9);
    println!("| arm | best wall (ms) |");
    println!("|---|---|");
    println!("| off | {:.3} |", off_best.as_secs_f64() * 1e3);
    println!("| collect+export | {:.3} |", collect_best.as_secs_f64() * 1e3);
    println!("| collect+sampler | {:.3} |", sampler_best.as_secs_f64() * 1e3);
    println!(
        "\noverhead ratio: {overhead_ratio:.4} ({events} events, {dropped} dropped, \
         jsonl {} B, chrome {} B)",
        jsonl_bytes.len(),
        chrome_bytes.len()
    );
    println!("sampler ratio: {sampler_ratio:.4} ({timeline_samples} timeline samples)");

    // Trace artifacts for the CI upload (Perfetto walkthrough in README).
    let dir = std::env::var("TRACE_OVERHEAD_DIR").unwrap_or_else(|_| ".".into());
    let timeline_raw = timeline_bytes.into_bytes();
    for (name, bytes) in [
        ("trace.jsonl", &jsonl_bytes),
        ("trace.chrome.json", &chrome_bytes),
        ("timeline.jsonl", &timeline_raw),
    ] {
        let path = format!("{dir}/{name}");
        match std::fs::write(&path, bytes) {
            Ok(()) => println!("(trace written to {path})"),
            Err(e) => eprintln!("warning: cannot write {path}: {e}"),
        }
    }

    // JSON first, asserts after — a failing run still leaves its data
    // behind for diagnosis (CI uploads the artifact even on failure).
    let mut json = String::from("{\n  \"bench\": \"trace_overhead\",\n");
    let _ = writeln!(json, "  \"tenants\": {tenants},");
    let _ = writeln!(json, "  \"blocks_per_file\": {blocks},");
    let _ = writeln!(json, "  \"block_len\": {block_len},");
    let _ = writeln!(json, "  \"samples\": {samples},");
    let _ = writeln!(json, "  \"off_ms\": {:.6},", off_best.as_secs_f64() * 1e3);
    let _ = writeln!(json, "  \"collect_ms\": {:.6},", collect_best.as_secs_f64() * 1e3);
    let _ = writeln!(json, "  \"sampler_ms\": {:.6},", sampler_best.as_secs_f64() * 1e3);
    let _ = writeln!(json, "  \"events\": {events},");
    let _ = writeln!(json, "  \"dropped\": {dropped},");
    let _ = writeln!(json, "  \"timeline_samples\": {timeline_samples},");
    let _ = writeln!(json, "  \"overhead_ratio\": {overhead_ratio:.6},");
    let _ = writeln!(json, "  \"sampler_overhead_ratio\": {sampler_ratio:.6}");
    json.push_str("}\n");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_trace_overhead.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("(json written to {out})"),
        Err(e) => eprintln!("warning: cannot write {out}: {e}"),
    }

    // Structural claims (the ratio bound itself is the manifest guard's
    // job — wall-clock policy lives in one place):
    assert!(events > 0, "a traced run must record events");
    assert_eq!(dropped, 0, "the default ring must not overflow on this workload");
    assert!(
        jsonl_bytes.starts_with(b"{\"kind\":\"trace_meta\""),
        "jsonl export must lead with the meta record"
    );
    assert!(chrome_bytes.starts_with(b"["), "chrome export must be an array");
    assert!(timeline_samples > 0, "the sampler arm must produce samples");
    assert!(
        timeline_raw.starts_with(b"{\"kind\":\"timeline_meta\""),
        "timeline export must lead with its meta record"
    );

    println!("\ntrace_overhead bench done");
}
