#!/usr/bin/env python3
"""Schema validation + summary for lerc flight-recorder traces.

The Rust exporters (rust/src/trace/sink.rs) write two artifacts:

  * trace.jsonl — one flat JSON object per line; the first line is a
    `trace_meta` header, every following line is one event.
  * trace.chrome.json — Chrome trace-event JSON (array form), loadable
    at ui.perfetto.dev or chrome://tracing.

This tool is the cross-language contract test: CI runs `lerc trace`,
then validates both files against the schema tables below, so a Rust
exporter drifting away from the documented shape fails the build rather
than silently producing Perfetto-unloadable output.

Usage:
    trace_report.py validate --jsonl trace.jsonl [--chrome trace.chrome.json]
    trace_report.py summary trace.jsonl

Exit codes: 0 = OK, 1 = validation failure, 2 = usage error.
"""

import argparse
import json
import re
import sys
from collections import Counter

SCHEMA_VERSION = 1

# Field schema per event kind: name -> required type. `str` fields that
# carry block ids must additionally match BLOCK_RE.
_TASK_WORKER = {"task": int, "worker": int}
_BLOCK_WORKER = {"block": str, "worker": int}
EVENT_FIELDS = {
    "task_admitted": {"job": int, "task": int},
    "task_ready": {"task": int},
    "task_dispatched": dict(_TASK_WORKER),
    "inputs_pinned": dict(_TASK_WORKER),
    "task_computed": dict(_TASK_WORKER),
    "task_published": {"task": int, "worker": int, "block": str},
    "block_inserted": dict(_BLOCK_WORKER),
    "block_evicted": dict(_BLOCK_WORKER),
    "block_demoted": dict(_BLOCK_WORKER),
    "block_restored": dict(_BLOCK_WORKER),
    "block_dropped": dict(_BLOCK_WORKER),
    "block_invalidated": dict(_BLOCK_WORKER),
    "recompute_planned": {"block": str, "task": int},
    "eviction_reported": {"block": str},
    "invalidation_broadcast": {"block": str},
    "ctrl_drained": {"worker": int, "applied": int},
    "ineffective_hit": {
        "task": int,
        "worker": int,
        "block": str,
        "blocking": str,
        "cause": str,
    },
    "worker_killed": {"worker": int},
    "worker_revived": {"worker": int},
    "worker_joined": {"worker": int},
    "group_migrated": {"group": int, "from": int, "to": int, "blocks": int},
    "scale_decision": {"action": str, "worker": int, "ready": int, "mem_used": int},
}
BASE_FIELDS = {"kind": str, "ts": int, "seq": int, "track": int}
CAUSES = {"evicted", "spilled-not-restored", "remote", "recomputing"}
ENGINES = {"sim", "threaded"}
CLOCKS = {"logical", "wall"}
BLOCK_RE = re.compile(r"^D\d+\[\d+\]$")


def _typed(obj, name, want):
    """True when obj[name] exists with exactly the wanted scalar type
    (bool is an int subclass in Python — reject it explicitly)."""
    v = obj.get(name)
    if want is int:
        return isinstance(v, int) and not isinstance(v, bool)
    return isinstance(v, want)


def validate_jsonl(text, log=print):
    """Validate a JSONL trace. Returns the list of error strings."""
    errors = []
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        return ["empty trace file"]

    try:
        meta = json.loads(lines[0])
    except ValueError as e:
        return [f"line 1: meta is not JSON: {e}"]
    if not isinstance(meta, dict) or meta.get("kind") != "trace_meta":
        return ["line 1: first record must be kind 'trace_meta'"]
    if meta.get("schema") != SCHEMA_VERSION:
        errors.append(f"meta: schema {meta.get('schema')!r} != {SCHEMA_VERSION}")
    if meta.get("engine") not in ENGINES:
        errors.append(f"meta: engine {meta.get('engine')!r} not in {sorted(ENGINES)}")
    if meta.get("clock") not in CLOCKS:
        errors.append(f"meta: clock {meta.get('clock')!r} not in {sorted(CLOCKS)}")
    for name in ("workers", "dropped", "events"):
        if not _typed(meta, name, int):
            errors.append(f"meta: {name!r} missing or not an integer")
    workers = meta.get("workers") if _typed(meta, "workers", int) else None
    declared = meta.get("events") if _typed(meta, "events", int) else None
    dropped = meta.get("dropped") if _typed(meta, "dropped", int) else None
    if declared is not None and declared != len(lines) - 1:
        errors.append(
            f"meta declares {declared} events but the file holds {len(lines) - 1}"
        )

    prev_seq = None
    for no, ln in enumerate(lines[1:], start=2):
        where = f"line {no}"
        try:
            ev = json.loads(ln)
        except ValueError as e:
            errors.append(f"{where}: not JSON: {e}")
            continue
        if not isinstance(ev, dict):
            errors.append(f"{where}: event is not an object")
            continue
        bad = False
        for name, want in BASE_FIELDS.items():
            if not _typed(ev, name, want):
                errors.append(f"{where}: {name!r} missing or mistyped")
                bad = True
        if bad:
            continue
        kind = ev["kind"]
        fields = EVENT_FIELDS.get(kind)
        if fields is None:
            errors.append(f"{where}: unknown event kind {kind!r}")
            continue
        for name, want in fields.items():
            if not _typed(ev, name, want):
                errors.append(f"{where}: {kind}: {name!r} missing or mistyped")
            elif want is str and name in ("block", "blocking"):
                if not BLOCK_RE.match(ev[name]):
                    errors.append(
                        f"{where}: {kind}: {name}={ev[name]!r} is not a block id"
                    )
        extra = set(ev) - set(BASE_FIELDS) - set(fields)
        if extra:
            errors.append(f"{where}: {kind}: unexpected fields {sorted(extra)}")
        if kind == "ineffective_hit" and ev.get("cause") not in CAUSES:
            errors.append(f"{where}: cause {ev.get('cause')!r} not in {sorted(CAUSES)}")
        if workers is not None and ev["track"] > workers:
            errors.append(
                f"{where}: track {ev['track']} exceeds worker ceiling {workers} "
                "(tracks are 0=driver, 1+w=worker w; meta 'workers' is the "
                "topology ceiling, so mid-run joins stay in range)"
            )
        if prev_seq is not None and ev["seq"] <= prev_seq:
            errors.append(f"{where}: seq {ev['seq']} not after {prev_seq}")
        prev_seq = ev["seq"]
    # Drop-counter consistency: the recorder allocates a sequence number
    # before the ring-full check, so total emissions == retained events
    # + dropped. The highest retained seq must land inside that range —
    # with dropped == 0 it must be exactly events - 1.
    if dropped is not None and prev_seq is not None:
        n = len(lines) - 1
        emitted = prev_seq + 1
        if not n <= emitted <= n + dropped:
            errors.append(
                f"meta: dropped={dropped} inconsistent with max seq "
                f"{prev_seq} over {n} events (expected {n} <= max_seq+1 "
                f"<= {n + dropped})"
            )
    return errors


def validate_chrome(text, log=print):
    """Validate a Chrome trace-event JSON array. Returns error strings."""
    errors = []
    try:
        doc = json.loads(text)
    except ValueError as e:
        return [f"not JSON: {e}"]
    if not isinstance(doc, list):
        return ["top level must be a JSON array (the trace-event array form)"]
    named_tids = set()
    for i, ev in enumerate(doc):
        where = f"event {i}"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("M", "X", "i", "C"):
            errors.append(f"{where}: ph {ph!r} not one of M/X/i/C")
            continue
        # Counter events carry no tid: Perfetto keys counter tracks on
        # (pid, name) alone.
        required = ("name", "pid") if ph == "C" else ("name", "pid", "tid")
        for name in required:
            if name not in ev:
                errors.append(f"{where}: missing {name!r}")
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                errors.append(f"{where}: metadata name {ev.get('name')!r}")
            elif ev["name"] == "thread_name":
                named_tids.add(ev.get("tid"))
            if "name" not in ev.get("args", {}):
                errors.append(f"{where}: metadata args lack a 'name'")
        elif ph == "X":
            for name in ("ts", "dur"):
                if not isinstance(ev.get(name), (int, float)):
                    errors.append(f"{where}: span {name!r} missing or not numeric")
        elif ph == "i":
            if ev.get("s") != "t":
                errors.append(f"{where}: instant scope {ev.get('s')!r} != 't'")
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"{where}: instant 'ts' missing or not numeric")
        elif ph == "C":
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"{where}: counter 'ts' missing or not numeric")
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where}: counter args missing or empty")
            elif any(
                not isinstance(v, (int, float)) or isinstance(v, bool)
                for v in args.values()
            ):
                errors.append(f"{where}: counter args must be numeric series")
    # Every span/instant must land on a named track, or Perfetto renders
    # it on an anonymous row.
    for i, ev in enumerate(doc):
        if isinstance(ev, dict) and ev.get("ph") in ("X", "i"):
            if ev.get("tid") not in named_tids:
                errors.append(f"event {i}: tid {ev.get('tid')!r} has no thread_name")
    return errors


def percentile(sorted_vals, p):
    """Nearest-rank percentile of an ascending list (None when empty)."""
    if not sorted_vals:
        return None
    rank = max(1, -(-len(sorted_vals) * p // 100))  # ceil without math
    return sorted_vals[int(rank) - 1]


def fmt_ns(ns):
    if ns is None:
        return "-"
    if ns < 1_000:
        return f"{ns}ns"
    if ns < 1_000_000:
        return f"{ns / 1e3:.2f}us"
    if ns < 1_000_000_000:
        return f"{ns / 1e6:.2f}ms"
    return f"{ns / 1e9:.2f}s"


def summarize(text):
    """Build the summary dict for a (validated) JSONL trace."""
    lines = [ln for ln in text.splitlines() if ln.strip()]
    meta = json.loads(lines[0]) if lines else {}
    kinds = Counter()
    causes = Counter()
    blocking = Counter()
    ready, disp = {}, {}
    waits, lats = [], []
    for ln in lines[1:]:
        ev = json.loads(ln)
        kind = ev.get("kind")
        kinds[kind] += 1
        if kind == "ineffective_hit":
            causes[ev.get("cause")] += 1
            blocking[ev.get("blocking")] += 1
        elif kind == "task_ready":
            ready[ev.get("task")] = ev.get("ts", 0)
        elif kind == "task_dispatched":
            t = ev.get("task")
            disp[t] = ev.get("ts", 0)
            if t in ready:
                waits.append(max(0, ev.get("ts", 0) - ready.pop(t)))
        elif kind == "task_published":
            t = ev.get("task")
            if t in disp:
                lats.append(max(0, ev.get("ts", 0) - disp.pop(t)))
    waits.sort()
    lats.sort()
    return {
        "meta": meta,
        "kinds": dict(kinds),
        "causes": dict(causes),
        "top_blocking": blocking.most_common(5),
        "task_latency": {p: percentile(lats, p) for p in (50, 95, 99)},
        "queue_wait": {p: percentile(waits, p) for p in (50, 95, 99)},
    }


def print_summary(s, log=print):
    meta = s["meta"]
    log(
        f"trace: engine={meta.get('engine')} clock={meta.get('clock')} "
        f"workers={meta.get('workers')} events={meta.get('events')} "
        f"dropped={meta.get('dropped')}"
    )
    log("events by kind:")
    for kind, n in sorted(s["kinds"].items(), key=lambda kv: (-kv[1], kv[0])):
        log(f"  {kind:<24} {n}")
    if s["causes"]:
        log("ineffective-hit causes:")
        for cause, n in sorted(s["causes"].items(), key=lambda kv: (-kv[1], kv[0])):
            log(f"  {cause:<24} {n}")
    if s["top_blocking"]:
        log("top blocking blocks:")
        for block, n in s["top_blocking"]:
            log(f"  {block:<24} {n}")
    lat, wait = s["task_latency"], s["queue_wait"]
    log("latency (dispatch→publish): " + "  ".join(
        f"p{p}={fmt_ns(lat[p])}" for p in (50, 95, 99)))
    log("queue wait (ready→dispatch): " + "  ".join(
        f"p{p}={fmt_ns(wait[p])}" for p in (50, 95, 99)))


def main(argv=None):
    parser = argparse.ArgumentParser(
        prog="trace_report.py",
        description="Validate and summarize lerc flight-recorder traces.",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="schema-check trace artifacts")
    v.add_argument("--jsonl", help="trace.jsonl path")
    v.add_argument("--chrome", help="trace.chrome.json path")
    s = sub.add_parser("summary", help="summarize a trace.jsonl")
    s.add_argument("jsonl")
    args = parser.parse_args(argv)

    if args.cmd == "validate":
        if not args.jsonl and not args.chrome:
            print("validate: pass --jsonl and/or --chrome")
            return 2
        failures = 0
        for path, checker in ((args.jsonl, validate_jsonl), (args.chrome, validate_chrome)):
            if not path:
                continue
            try:
                with open(path) as f:
                    text = f.read()
            except OSError as e:
                print(f"{path}: cannot read: {e}")
                failures += 1
                continue
            errors = checker(text)
            if errors:
                failures += 1
                for err in errors[:25]:
                    print(f"{path}: {err}")
                if len(errors) > 25:
                    print(f"{path}: ... and {len(errors) - 25} more")
            else:
                print(f"{path}: OK")
        return 1 if failures else 0

    # summary
    try:
        with open(args.jsonl) as f:
            text = f.read()
    except OSError as e:
        print(f"{args.jsonl}: cannot read: {e}")
        return 1
    errors = validate_jsonl(text)
    if errors:
        for err in errors[:25]:
            print(f"{args.jsonl}: {err}")
        return 1
    print_summary(summarize(text))
    return 0


if __name__ == "__main__":
    sys.exit(main())
