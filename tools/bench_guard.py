#!/usr/bin/env python3
"""Manifest-driven CI guard for bench JSONs.

One manifest (rust/benches/baselines/manifest.json) describes every
guarded bench: which fresh JSON the bench emits, where its committed
baseline lives, which top-level scalar is the guarded metric, which
direction is "better", and how much relative regression is tolerated.
This replaces the per-bench guard scripts (tools/ctrl_plane_guard.py is
now a thin compatibility shim over this module).

Manifest entry schema (all paths relative to the working directory,
which in CI is the repository root):

    "ctrl_plane": {
      "fresh": "BENCH_ctrl_plane.json",
      "baseline": "rust/benches/baselines/ctrl_plane.json",
      "metric": "speedup_at_4",
      "direction": "higher",          # or "lower"
      "check": "tolerance",           # or "min_delta" / "ratchet"
      "tolerance": 0.30,              # relative regression allowed
      "min_delta": 1.0,               # min_delta checks only: absolute
                                      # floor (higher) / ceiling (lower)
                                      # the fresh metric must clear
      "min_to_promote": 0.70,         # optional: floor a fresh value
                                      # must clear to replace a pending
                                      # baseline
      "config_keys": ["tenants"]      # optional: top-level fields that
    }                                 # must match between fresh and
                                      # baseline (quick vs full configs
                                      # produce incomparable metrics)

Check types:
  * "tolerance" (default) — the fresh metric must not regress beyond
    `tolerance` relative to the committed baseline (a drift band).
  * "min_delta" — the fresh metric must clear the absolute `min_delta`
    bound, whatever the baseline says (an invariant floor, not a band:
    the spill bench guards "coordinated beats per-block by at least N
    recomputes" this way — a baseline drifting toward zero must never
    loosen the requirement). The baseline file still exists and is kept
    fresh by --refresh-pending so the artifact history stays uniform.
  * "ratchet" — guards exactly like "tolerance", but on --refresh-pending
    runs a direction-better fresh value REPLACES the committed baseline
    (the floor auto-raises as the implementation gets faster). The floor
    never lowers: a worse-but-within-tolerance run passes the guard and
    leaves the baseline untouched, so perf can only be banked, never
    quietly given back.

Guard rules, per bench:
  * A missing fresh JSON is a FAILURE — the bench did not run or did
    not write its output (the silently-missing-artifact hazard).
  * A baseline with `"pending": true` is a FAILURE unless
    --refresh-pending is given, in which case the fresh run's numbers
    are promoted over the baseline (refused if the fresh metric does
    not clear `min_to_promote` — enshrining a regressed run would mask
    the regression forever). CI runs the refresh before the guard and
    commits promoted baselines back on pushes to main with [skip ci].
  * Otherwise the fresh metric must not regress beyond `tolerance`
    relative to the baseline: for "higher" metrics the floor is
    `base - tolerance * |base|`, for "lower" the ceiling is
    `base + tolerance * |base|`.

Usage:
    bench_guard.py [--manifest rust/benches/baselines/manifest.json]
                   [--bench NAME]... [--refresh-pending]

Exit codes: 0 = all guarded benches OK, 1 = at least one failure,
2 = usage/manifest error.
"""

import argparse
import json
import os
import sys

DEFAULT_MANIFEST = os.path.join("rust", "benches", "baselines", "manifest.json")


def load_json(path):
    with open(path) as f:
        return json.load(f)


def guard_one(
    name,
    fresh_path,
    base_path,
    metric,
    direction="higher",
    check="tolerance",
    tolerance=0.30,
    min_delta=None,
    min_to_promote=None,
    config_keys=(),
    refresh_pending=False,
    log=print,
):
    """Guard one bench. Returns True when the guard passes."""
    if direction not in ("higher", "lower"):
        log(f"[{name}] FAIL: unknown direction {direction!r}")
        return False
    if check not in ("tolerance", "min_delta", "ratchet"):
        log(f"[{name}] FAIL: unknown check type {check!r}")
        return False
    if check == "min_delta" and min_delta is None:
        log(f"[{name}] FAIL: check 'min_delta' requires a 'min_delta' bound")
        return False
    if not os.path.exists(fresh_path):
        log(
            f"[{name}] FAIL: fresh bench JSON {fresh_path} is missing — the bench "
            "did not run or did not write its output"
        )
        return False
    try:
        fresh = load_json(fresh_path)
    except ValueError as e:
        log(f"[{name}] FAIL: cannot parse {fresh_path}: {e}")
        return False
    if metric not in fresh or fresh[metric] is None:
        log(f"[{name}] FAIL: fresh JSON {fresh_path} has no metric {metric!r}")
        return False
    fresh_value = float(fresh[metric])

    try:
        base = load_json(base_path)
    except FileNotFoundError:
        log(f"[{name}] FAIL: committed baseline {base_path} is missing")
        return False
    except ValueError as e:
        log(f"[{name}] FAIL: cannot parse baseline {base_path}: {e}")
        return False

    if base.get("pending"):
        if not refresh_pending:
            log(
                f"[{name}] FAIL: the committed baseline is still 'pending': true — "
                f"it guards nothing. Run the bench and copy {fresh_path} over "
                f"{base_path} (CI does this automatically via --refresh-pending "
                "on pushes to main)."
            )
            return False
        if min_to_promote is not None:
            regressed = (
                fresh_value < float(min_to_promote)
                if direction == "higher"
                else fresh_value > float(min_to_promote)
            )
            if regressed:
                log(
                    f"[{name}] FAIL: refusing to promote a regressed run as "
                    f"baseline: {metric} {fresh_value:.4f} does not clear the "
                    f"promotion bound {float(min_to_promote):.4f}"
                )
                return False
        with open(fresh_path) as f:
            content = f.read()
        with open(base_path, "w") as out:
            out.write(content)
        log(
            f"[{name}] baseline was pending: refreshed {base_path} from "
            f"{fresh_path} ({metric} {fresh_value:.4f}); commit it to make "
            "this stick"
        )
        base = fresh

    if check == "min_delta":
        # Invariant floor: the fresh value must clear the absolute bound
        # regardless of baseline drift (the baseline file is kept only so
        # --refresh-pending and the artifact history stay uniform).
        bound = float(min_delta)
        ok = fresh_value >= bound if direction == "higher" else fresh_value <= bound
        word = "floor" if direction == "higher" else "ceiling"
        log(f"[{name}] {metric}: fresh {fresh_value:.4f} vs min_delta {word} {bound:.4f}")
        if not ok:
            log(f"[{name}] FAIL: {metric} does not clear the min_delta {word}")
            return False
        log(f"[{name}] OK")
        return True

    if metric not in base or base[metric] is None:
        log(f"[{name}] FAIL: baseline {base_path} has no metric {metric!r}")
        return False
    # Different bench configurations (quick CI smoke vs full local run)
    # produce incomparable metrics even when both are deterministic:
    # refuse the comparison instead of firing a spurious verdict.
    for key in config_keys:
        if key in fresh and key in base and fresh[key] != base[key]:
            log(
                f"[{name}] FAIL: fresh and baseline were produced by different "
                f"bench configurations ({key}: fresh {fresh[key]!r} vs baseline "
                f"{base[key]!r}) — their metrics are not comparable. Re-run the "
                "bench with the baseline's configuration (CI uses the *_QUICK "
                "smoke settings)."
            )
            return False
    base_value = float(base[metric])
    slack = tolerance * abs(base_value)
    if direction == "higher":
        bound = base_value - slack
        ok = fresh_value >= bound
        word = "floor"
    else:
        bound = base_value + slack
        ok = fresh_value <= bound
        word = "ceiling"
    log(
        f"[{name}] {metric}: fresh {fresh_value:.4f} vs baseline {base_value:.4f} "
        f"({word} {bound:.4f}, tolerance {tolerance:.0%})"
    )
    if not ok:
        log(f"[{name}] FAIL: {metric} regressed beyond tolerance")
        return False
    if check == "ratchet" and refresh_pending:
        improved = (
            fresh_value > base_value
            if direction == "higher"
            else fresh_value < base_value
        )
        if improved:
            # Bank the improvement: the fresh run becomes the committed
            # floor. A worse (but in-band) run never rewrites it, so the
            # ratchet only ever tightens.
            with open(fresh_path) as f:
                content = f.read()
            with open(base_path, "w") as out:
                out.write(content)
            log(
                f"[{name}] ratchet: baseline raised {base_value:.4f} -> "
                f"{fresh_value:.4f}; commit {base_path} to make this stick"
            )
    log(f"[{name}] OK")
    return True


def main(argv=None):
    parser = argparse.ArgumentParser(
        prog="bench_guard.py",
        description="Guard bench JSONs against committed baselines via a manifest.",
    )
    parser.add_argument("--manifest", default=DEFAULT_MANIFEST)
    parser.add_argument(
        "--bench",
        action="append",
        default=None,
        help="guard only this bench (repeatable; default: every manifest entry)",
    )
    parser.add_argument(
        "--refresh-pending",
        action="store_true",
        help="promote fresh numbers over baselines still marked pending",
    )
    args = parser.parse_args(argv)

    try:
        manifest = load_json(args.manifest)
    except (OSError, ValueError) as e:
        print(f"cannot load manifest {args.manifest}: {e}")
        return 2
    benches = manifest.get("benches")
    if not isinstance(benches, dict) or not benches:
        print(f"manifest {args.manifest} has no 'benches' table")
        return 2

    selected = args.bench or sorted(benches)
    unknown = [b for b in selected if b not in benches]
    if unknown:
        print(f"unknown bench(es) {unknown}; manifest has {sorted(benches)}")
        return 2

    failures = 0
    for name in selected:
        spec = benches[name]
        ok = guard_one(
            name,
            fresh_path=spec.get("fresh", f"BENCH_{name}.json"),
            base_path=spec["baseline"],
            metric=spec["metric"],
            direction=spec.get("direction", "higher"),
            check=spec.get("check", "tolerance"),
            tolerance=float(spec.get("tolerance", 0.30)),
            min_delta=spec.get("min_delta"),
            min_to_promote=spec.get("min_to_promote"),
            config_keys=spec.get("config_keys", ()),
            refresh_pending=args.refresh_pending,
        )
        if not ok:
            failures += 1
    if failures:
        print(f"{failures}/{len(selected)} guarded bench(es) FAILED")
        return 1
    print(f"all {len(selected)} guarded bench(es) OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
