"""Unit tests for trace_report.py (run via the CI lint job's
`python3 -m unittest discover -s tools`)."""

import json
import unittest

import trace_report as tr


def meta_line(**over):
    meta = {
        "kind": "trace_meta",
        "schema": 1,
        "engine": "sim",
        "clock": "logical",
        "workers": 1,
        "dropped": 0,
        "events": 0,
    }
    meta.update(over)
    return json.dumps(meta)


def jsonl(evts, **meta_over):
    meta_over.setdefault("events", len(evts))
    lines = [meta_line(**meta_over)]
    lines += [json.dumps(e) for e in evts]
    return "\n".join(lines) + "\n"


def ev(kind, seq, track=0, ts=0, **fields):
    base = {"kind": kind, "ts": ts, "seq": seq, "track": track}
    base.update(fields)
    return base


GOOD_EVENTS = [
    ev("task_admitted", 0, job=0, task=1),
    ev("task_ready", 1, task=1),
    ev("task_dispatched", 2, ts=10, task=1, worker=0),
    ev("inputs_pinned", 3, track=1, ts=12, task=1, worker=0),
    ev(
        "ineffective_hit",
        4,
        track=1,
        ts=12,
        task=1,
        worker=0,
        block="D0[1]",
        blocking="D1[1]",
        cause="evicted",
    ),
    ev("task_computed", 5, track=1, ts=20, task=1, worker=0),
    ev("block_inserted", 6, track=1, ts=21, block="D2[0]", worker=0),
    ev("task_published", 7, track=1, ts=22, task=1, worker=0, block="D2[0]"),
    ev("scale_decision", 8, ts=23, action="up", worker=1, ready=4, mem_used=4096),
    ev("worker_joined", 9, ts=24, worker=1),
    # "from" is a Python keyword, so the topology fields go in as a dict.
    ev("group_migrated", 10, ts=25, group=3, blocks=2, **{"from": 0, "to": 1}),
]


class ValidateJsonlTests(unittest.TestCase):
    def test_good_trace_passes(self):
        self.assertEqual(tr.validate_jsonl(jsonl(GOOD_EVENTS)), [])

    def test_empty_file_fails(self):
        self.assertTrue(tr.validate_jsonl(""))

    def test_first_line_must_be_meta(self):
        text = json.dumps(ev("task_ready", 0, task=1))
        errors = tr.validate_jsonl(text)
        self.assertTrue(any("trace_meta" in e for e in errors))

    def test_event_count_mismatch(self):
        errors = tr.validate_jsonl(jsonl(GOOD_EVENTS, events=99))
        self.assertTrue(any("declares 99" in e for e in errors))

    def test_unknown_kind_and_missing_field(self):
        bad = [ev("task_teleported", 0), ev("task_dispatched", 1, task=1)]
        errors = tr.validate_jsonl(jsonl(bad))
        self.assertTrue(any("unknown event kind" in e for e in errors))
        self.assertTrue(any("'worker' missing" in e for e in errors))

    def test_bad_cause_and_bad_block_id(self):
        bad = [
            ev(
                "ineffective_hit",
                0,
                task=1,
                worker=0,
                block="D0[1]",
                blocking="not-a-block",
                cause="sunspots",
            )
        ]
        errors = tr.validate_jsonl(jsonl(bad))
        self.assertTrue(any("not a block id" in e for e in errors))
        self.assertTrue(any("sunspots" in e for e in errors))

    def test_seq_must_increase(self):
        bad = [ev("task_ready", 5, task=1), ev("task_ready", 5, task=2)]
        errors = tr.validate_jsonl(jsonl(bad))
        self.assertTrue(any("seq" in e for e in errors))

    def test_track_bounded_by_worker_ceiling(self):
        bad = [ev("task_ready", 0, track=7, task=1)]
        errors = tr.validate_jsonl(jsonl(bad, workers=1))
        self.assertTrue(any("exceeds worker ceiling" in e for e in errors))
        # A joined worker's track is in range when the meta declares the
        # topology ceiling rather than the starting fleet size.
        ok = [ev("worker_joined", 0, track=8, ts=1, worker=7)]
        self.assertEqual(tr.validate_jsonl(jsonl(ok, workers=8)), [])

    def test_dropped_counter_consistency(self):
        # dropped=0 requires contiguous seqs: a gap means the meta lies.
        gap = [ev("task_ready", 0, task=1), ev("task_ready", 2, task=2)]
        errors = tr.validate_jsonl(jsonl(gap, dropped=0))
        self.assertTrue(any("dropped=0 inconsistent" in e for e in errors))
        # The same gap is consistent once the meta owns up to one drop.
        self.assertEqual(tr.validate_jsonl(jsonl(gap, dropped=1)), [])
        # But a seq beyond events+dropped is inconsistent again.
        far = [ev("task_ready", 0, task=1), ev("task_ready", 9, task=2)]
        errors = tr.validate_jsonl(jsonl(far, dropped=1))
        self.assertTrue(any("inconsistent with max seq" in e for e in errors))

    def test_unexpected_extra_field(self):
        bad = [ev("task_ready", 0, task=1, surprise=9)]
        errors = tr.validate_jsonl(jsonl(bad))
        self.assertTrue(any("unexpected fields" in e for e in errors))

    def test_topology_kinds_validate_fields(self):
        # Missing "from" on a migration, and a non-string action on a
        # scale decision, are both schema errors.
        bad = [
            ev("group_migrated", 0, group=3, blocks=2, to=1),
            ev("scale_decision", 1, action=2, worker=1, ready=4, mem_used=0),
        ]
        errors = tr.validate_jsonl(jsonl(bad), log=lambda *_: None)
        self.assertEqual(len(errors), 2)
        self.assertIn("from", errors[0])
        self.assertIn("action", errors[1])

    def test_bool_is_not_an_int(self):
        bad = [ev("task_ready", 0, task=True)]
        errors = tr.validate_jsonl(jsonl(bad))
        self.assertTrue(any("'task' missing or mistyped" in e for e in errors))


class ValidateChromeTests(unittest.TestCase):
    def chrome(self):
        return [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 0,
                "tid": 0,
                "args": {"name": "lerc sim"},
            },
            {
                "name": "thread_name",
                "ph": "M",
                "pid": 0,
                "tid": 1,
                "args": {"name": "worker-0"},
            },
            {
                "name": "T1 compute",
                "cat": "task",
                "ph": "X",
                "ts": 1.0,
                "dur": 2.0,
                "pid": 0,
                "tid": 1,
                "args": {"task": 1},
            },
            {
                "name": "block_inserted",
                "cat": "cache",
                "ph": "i",
                "s": "t",
                "ts": 3.0,
                "pid": 0,
                "tid": 1,
                "args": {"block": "D0[0]", "worker": 0},
            },
        ]

    def test_good_chrome_passes(self):
        self.assertEqual(tr.validate_chrome(json.dumps(self.chrome())), [])

    def test_top_level_must_be_array(self):
        self.assertTrue(tr.validate_chrome(json.dumps({"ph": "X"})))

    def test_span_needs_duration(self):
        doc = self.chrome()
        del doc[2]["dur"]
        errors = tr.validate_chrome(json.dumps(doc))
        self.assertTrue(any("'dur'" in e for e in errors))

    def test_instant_needs_thread_scope(self):
        doc = self.chrome()
        doc[3]["s"] = "g"
        errors = tr.validate_chrome(json.dumps(doc))
        self.assertTrue(any("scope" in e for e in errors))

    def test_events_must_land_on_named_tracks(self):
        doc = self.chrome()
        doc[2]["tid"] = 42
        errors = tr.validate_chrome(json.dumps(doc))
        self.assertTrue(any("no thread_name" in e for e in errors))

    def test_counter_tracks_validate(self):
        # Timeline counter events carry no tid: Perfetto keys counter
        # tracks on (pid, name) alone.
        doc = self.chrome()
        doc.append(
            {
                "name": "ready_depth",
                "cat": "timeline",
                "ph": "C",
                "ts": 4.0,
                "pid": 0,
                "args": {"ready": 3},
            }
        )
        self.assertEqual(tr.validate_chrome(json.dumps(doc)), [])
        doc[-1]["args"] = {"ready": "three"}
        errors = tr.validate_chrome(json.dumps(doc))
        self.assertTrue(any("numeric series" in e for e in errors))
        del doc[-1]["args"]
        errors = tr.validate_chrome(json.dumps(doc))
        self.assertTrue(any("args missing or empty" in e for e in errors))


class SummaryTests(unittest.TestCase):
    def test_summary_counts_and_latency(self):
        s = tr.summarize(jsonl(GOOD_EVENTS))
        self.assertEqual(s["kinds"]["task_dispatched"], 1)
        self.assertEqual(s["causes"], {"evicted": 1})
        self.assertEqual(s["top_blocking"], [("D1[1]", 1)])
        # dispatched at ts=10, published at ts=22 -> latency 12.
        self.assertEqual(s["task_latency"][50], 12)
        # ready at ts=0, dispatched at ts=10 -> wait 10.
        self.assertEqual(s["queue_wait"][99], 10)

    def test_percentile_nearest_rank(self):
        self.assertEqual(tr.percentile([1, 2, 3, 4], 50), 2)
        self.assertEqual(tr.percentile([1, 2, 3, 4], 99), 4)
        self.assertIsNone(tr.percentile([], 50))

    def test_fmt_ns_scales(self):
        self.assertEqual(tr.fmt_ns(None), "-")
        self.assertEqual(tr.fmt_ns(5), "5ns")
        self.assertIn("us", tr.fmt_ns(5_000))
        self.assertIn("ms", tr.fmt_ns(5_000_000))
        self.assertIn("s", tr.fmt_ns(5_000_000_000))


class MainTests(unittest.TestCase):
    def test_validate_cli_roundtrip(self):
        import tempfile, os

        with tempfile.TemporaryDirectory() as d:
            jp = os.path.join(d, "trace.jsonl")
            with open(jp, "w") as f:
                f.write(jsonl(GOOD_EVENTS))
            self.assertEqual(tr.main(["validate", "--jsonl", jp]), 0)
            self.assertEqual(tr.main(["summary", jp]), 0)
            with open(jp, "w") as f:
                f.write("not json\n")
            self.assertEqual(tr.main(["validate", "--jsonl", jp]), 1)


if __name__ == "__main__":
    unittest.main()
