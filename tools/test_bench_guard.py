#!/usr/bin/env python3
"""Unit tests for tools/bench_guard.py (run via `python3 -m unittest
discover -s tools` — the CI lint job does exactly that).

Covers the tolerance pass/fail paths, the pending-promotion flow
(promotion, refusal below the bound, hard failure without
--refresh-pending), the missing-fresh-JSON hazard, manifest-driven
multi-bench runs, and the ctrl_plane_guard.py compatibility shim.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_guard  # noqa: E402
import ctrl_plane_guard  # noqa: E402


def write_json(path, obj):
    with open(path, "w") as f:
        json.dump(obj, f)


class GuardOneTests(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.fresh = os.path.join(self.dir.name, "fresh.json")
        self.base = os.path.join(self.dir.name, "base.json")
        self.logs = []

    def tearDown(self):
        self.dir.cleanup()

    def guard(self, **kw):
        kw.setdefault("fresh_path", self.fresh)
        kw.setdefault("base_path", self.base)
        kw.setdefault("metric", "speedup")
        return bench_guard.guard_one("t", log=self.logs.append, **kw)

    def test_within_tolerance_passes(self):
        write_json(self.fresh, {"speedup": 1.3})
        write_json(self.base, {"speedup": 1.5})
        self.assertTrue(self.guard(tolerance=0.30))

    def test_regression_beyond_tolerance_fails(self):
        write_json(self.fresh, {"speedup": 1.0})
        write_json(self.base, {"speedup": 1.5})
        self.assertFalse(self.guard(tolerance=0.30))
        self.assertTrue(any("regressed" in m for m in self.logs))

    def test_lower_is_better_direction(self):
        write_json(self.fresh, {"speedup": 1.05})
        write_json(self.base, {"speedup": 1.0})
        self.assertTrue(self.guard(direction="lower", tolerance=0.10))
        write_json(self.fresh, {"speedup": 1.5})
        self.assertFalse(self.guard(direction="lower", tolerance=0.10))

    def test_missing_fresh_json_fails(self):
        write_json(self.base, {"speedup": 1.5})
        self.assertFalse(self.guard())
        self.assertTrue(any("missing" in m for m in self.logs))

    def test_missing_metric_fails(self):
        write_json(self.fresh, {"other": 1.0})
        write_json(self.base, {"speedup": 1.5})
        self.assertFalse(self.guard())

    def test_pending_baseline_hard_fails_without_refresh(self):
        write_json(self.fresh, {"speedup": 1.4})
        write_json(self.base, {"pending": True, "speedup": None})
        self.assertFalse(self.guard())
        self.assertTrue(any("pending" in m for m in self.logs))

    def test_pending_baseline_promotes_with_refresh(self):
        write_json(self.fresh, {"speedup": 1.4, "extra": [1, 2]})
        write_json(self.base, {"pending": True, "speedup": None})
        self.assertTrue(self.guard(refresh_pending=True, min_to_promote=0.7))
        with open(self.base) as f:
            promoted = json.load(f)
        self.assertEqual(promoted["speedup"], 1.4)
        self.assertNotIn("pending", promoted)
        # Subsequent guard runs compare against the promoted numbers.
        self.assertTrue(self.guard(tolerance=0.30))

    def test_pending_promotion_refuses_regressed_run(self):
        write_json(self.fresh, {"speedup": 0.5})
        write_json(self.base, {"pending": True, "speedup": None})
        self.assertFalse(self.guard(refresh_pending=True, min_to_promote=0.7))
        with open(self.base) as f:
            self.assertTrue(json.load(f)["pending"], "baseline must stay pending")

    def test_config_mismatch_refuses_comparison(self):
        write_json(self.fresh, {"speedup": 1.5, "blocks": 24})
        write_json(self.base, {"speedup": 1.5, "blocks": 12})
        self.assertFalse(self.guard(config_keys=["blocks"]))
        self.assertTrue(any("not comparable" in m for m in self.logs))
        # Matching configs (or keys absent on one side) compare normally.
        write_json(self.fresh, {"speedup": 1.5, "blocks": 12})
        self.assertTrue(self.guard(config_keys=["blocks"]))
        write_json(self.base, {"speedup": 1.5})
        self.assertTrue(self.guard(config_keys=["blocks"]))

    def test_pending_promotion_skips_config_check(self):
        # A pending placeholder has no config fields; promotion adopts
        # the fresh run's config wholesale.
        write_json(self.fresh, {"speedup": 1.4, "blocks": 12})
        write_json(self.base, {"pending": True, "speedup": None})
        self.assertTrue(
            self.guard(refresh_pending=True, min_to_promote=0.7, config_keys=["blocks"])
        )
        with open(self.base) as f:
            self.assertEqual(json.load(f)["blocks"], 12)

    def test_min_delta_is_an_absolute_floor_not_a_band(self):
        # The baseline is far better than the floor; a fresh value that
        # clears the floor passes even though it would fail a tolerance
        # comparison against the baseline.
        write_json(self.fresh, {"speedup": 2.0})
        write_json(self.base, {"speedup": 10.0})
        self.assertTrue(self.guard(check="min_delta", min_delta=1.0, tolerance=0.1))
        # And a baseline drifting toward zero must never loosen the bound.
        write_json(self.fresh, {"speedup": 0.0})
        write_json(self.base, {"speedup": 0.0})
        self.assertFalse(self.guard(check="min_delta", min_delta=1.0))
        self.assertTrue(any("min_delta" in m for m in self.logs))

    def test_min_delta_direction_lower_is_a_ceiling(self):
        write_json(self.fresh, {"speedup": 0.5})
        write_json(self.base, {"speedup": 99.0})
        self.assertTrue(
            self.guard(check="min_delta", min_delta=1.0, direction="lower")
        )
        write_json(self.fresh, {"speedup": 1.5})
        self.assertFalse(
            self.guard(check="min_delta", min_delta=1.0, direction="lower")
        )

    def test_min_delta_requires_bound_and_valid_check_type(self):
        write_json(self.fresh, {"speedup": 2.0})
        write_json(self.base, {"speedup": 2.0})
        self.assertFalse(self.guard(check="min_delta"))
        self.assertTrue(any("requires a 'min_delta' bound" in m for m in self.logs))
        self.assertFalse(self.guard(check="banana"))

    def test_min_delta_pending_baseline_still_hard_fails_and_promotes(self):
        # The pending flow is unchanged for min_delta benches: a pending
        # baseline fails without --refresh-pending, and promotion writes
        # the fresh numbers before the floor check runs.
        write_json(self.fresh, {"speedup": 3.0})
        write_json(self.base, {"pending": True})
        self.assertFalse(self.guard(check="min_delta", min_delta=1.0))
        self.assertTrue(
            self.guard(check="min_delta", min_delta=1.0, refresh_pending=True)
        )
        with open(self.base) as f:
            self.assertEqual(json.load(f)["speedup"], 3.0)

    def test_wall_clock_ceiling_flow(self):
        # The event_scale shape: direction "lower", a min_delta ceiling,
        # and a promotion bound that refuses to enshrine a slow run as
        # the baseline.
        write_json(self.fresh, {"speedup": 45.0})
        write_json(self.base, {"pending": True})
        self.assertFalse(
            self.guard(
                check="min_delta",
                min_delta=30.0,
                direction="lower",
                min_to_promote=30.0,
                refresh_pending=True,
            )
        )
        with open(self.base) as f:
            self.assertTrue(json.load(f)["pending"], "baseline must stay pending")
        # A run under the ceiling promotes and passes the guard.
        write_json(self.fresh, {"speedup": 12.0})
        self.assertTrue(
            self.guard(
                check="min_delta",
                min_delta=30.0,
                direction="lower",
                min_to_promote=30.0,
                refresh_pending=True,
            )
        )
        with open(self.base) as f:
            self.assertEqual(json.load(f)["speedup"], 12.0)

    def test_refresh_on_non_pending_baseline_only_guards(self):
        write_json(self.fresh, {"speedup": 1.4})
        write_json(self.base, {"speedup": 1.5})
        self.assertTrue(self.guard(refresh_pending=True, tolerance=0.30))
        with open(self.base) as f:
            self.assertEqual(json.load(f)["speedup"], 1.5, "baseline untouched")


class RatchetTests(unittest.TestCase):
    """The "ratchet" check: tolerance-style guarding plus a floor that
    auto-raises on --refresh-pending runs and never lowers."""

    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.fresh = os.path.join(self.dir.name, "fresh.json")
        self.base = os.path.join(self.dir.name, "base.json")
        self.logs = []

    def tearDown(self):
        self.dir.cleanup()

    def guard(self, **kw):
        kw.setdefault("fresh_path", self.fresh)
        kw.setdefault("base_path", self.base)
        kw.setdefault("metric", "ops")
        kw.setdefault("check", "ratchet")
        return bench_guard.guard_one("t", log=self.logs.append, **kw)

    def baseline(self):
        with open(self.base) as f:
            return json.load(f)

    def test_guards_like_tolerance(self):
        write_json(self.base, {"ops": 100.0})
        write_json(self.fresh, {"ops": 80.0})
        self.assertTrue(self.guard(tolerance=0.30))
        write_json(self.fresh, {"ops": 60.0})
        self.assertFalse(self.guard(tolerance=0.30))
        self.assertTrue(any("regressed" in m for m in self.logs))

    def test_refresh_raises_floor_on_improvement(self):
        write_json(self.base, {"ops": 100.0})
        write_json(self.fresh, {"ops": 150.0, "cfg": 7})
        self.assertTrue(self.guard(tolerance=0.30, refresh_pending=True))
        self.assertEqual(self.baseline()["ops"], 150.0)
        self.assertEqual(self.baseline()["cfg"], 7, "whole fresh JSON adopted")
        self.assertTrue(any("ratchet: baseline raised" in m for m in self.logs))
        # The raised floor now guards: the old value regresses beyond 30%.
        write_json(self.fresh, {"ops": 100.0})
        self.assertFalse(self.guard(tolerance=0.30))

    def test_refresh_never_lowers_floor(self):
        # Worse-but-in-band passes the guard yet leaves the floor alone.
        write_json(self.base, {"ops": 100.0})
        write_json(self.fresh, {"ops": 90.0})
        self.assertTrue(self.guard(tolerance=0.30, refresh_pending=True))
        self.assertEqual(self.baseline()["ops"], 100.0, "floor must not lower")

    def test_without_refresh_never_writes(self):
        write_json(self.base, {"ops": 100.0})
        write_json(self.fresh, {"ops": 150.0})
        self.assertTrue(self.guard(tolerance=0.30))
        self.assertEqual(self.baseline()["ops"], 100.0)

    def test_pending_baseline_promotes_then_ratchets(self):
        write_json(self.base, {"pending": True, "ops": None})
        write_json(self.fresh, {"ops": 100.0})
        self.assertFalse(self.guard(tolerance=0.30), "pending hard-fails")
        self.assertTrue(
            self.guard(tolerance=0.30, refresh_pending=True, min_to_promote=50.0)
        )
        self.assertEqual(self.baseline()["ops"], 100.0)
        self.assertNotIn("pending", self.baseline())
        write_json(self.fresh, {"ops": 120.0})
        self.assertTrue(self.guard(tolerance=0.30, refresh_pending=True))
        self.assertEqual(self.baseline()["ops"], 120.0)

    def test_lower_direction_ratchets_downward(self):
        write_json(self.base, {"ops": 10.0})
        write_json(self.fresh, {"ops": 8.0})
        self.assertTrue(
            self.guard(direction="lower", tolerance=0.30, refresh_pending=True)
        )
        self.assertEqual(self.baseline()["ops"], 8.0)
        write_json(self.fresh, {"ops": 9.0})
        self.assertTrue(
            self.guard(direction="lower", tolerance=0.30, refresh_pending=True)
        )
        self.assertEqual(self.baseline()["ops"], 8.0, "ceiling must not rise")


class ManifestTests(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.prev_cwd = os.getcwd()
        os.chdir(self.dir.name)
        self.manifest = "manifest.json"
        write_json(
            self.manifest,
            {
                "benches": {
                    "alpha": {
                        "fresh": "BENCH_alpha.json",
                        "baseline": "base_alpha.json",
                        "metric": "m",
                        "tolerance": 0.2,
                    },
                    "beta": {
                        "fresh": "BENCH_beta.json",
                        "baseline": "base_beta.json",
                        "metric": "m",
                        "direction": "lower",
                        "tolerance": 0.2,
                    },
                }
            },
        )

    def tearDown(self):
        os.chdir(self.prev_cwd)
        self.dir.cleanup()

    def test_all_benches_pass(self):
        write_json("BENCH_alpha.json", {"m": 2.0})
        write_json("base_alpha.json", {"m": 2.0})
        write_json("BENCH_beta.json", {"m": 1.0})
        write_json("base_beta.json", {"m": 1.0})
        self.assertEqual(bench_guard.main(["--manifest", self.manifest]), 0)

    def test_one_failure_fails_the_run(self):
        write_json("BENCH_alpha.json", {"m": 2.0})
        write_json("base_alpha.json", {"m": 2.0})
        write_json("BENCH_beta.json", {"m": 2.0})  # lower-is-better: regressed
        write_json("base_beta.json", {"m": 1.0})
        self.assertEqual(bench_guard.main(["--manifest", self.manifest]), 1)

    def test_bench_filter_selects_subset(self):
        write_json("BENCH_alpha.json", {"m": 2.0})
        write_json("base_alpha.json", {"m": 2.0})
        # beta's files don't exist, but it is filtered out.
        rc = bench_guard.main(["--manifest", self.manifest, "--bench", "alpha"])
        self.assertEqual(rc, 0)

    def test_unknown_bench_is_usage_error(self):
        rc = bench_guard.main(["--manifest", self.manifest, "--bench", "nope"])
        self.assertEqual(rc, 2)

    def test_missing_manifest_is_usage_error(self):
        self.assertEqual(bench_guard.main(["--manifest", "absent.json"]), 2)

    def test_refresh_pending_promotes_across_benches(self):
        write_json("BENCH_alpha.json", {"m": 2.0})
        write_json("base_alpha.json", {"pending": True, "m": None})
        write_json("BENCH_beta.json", {"m": 1.0})
        write_json("base_beta.json", {"m": 1.0})
        rc = bench_guard.main(["--manifest", self.manifest, "--refresh-pending"])
        self.assertEqual(rc, 0)
        with open("base_alpha.json") as f:
            self.assertEqual(json.load(f)["m"], 2.0)


class ShimTests(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.fresh = os.path.join(self.dir.name, "BENCH_ctrl_plane.json")
        self.base = os.path.join(self.dir.name, "ctrl_plane.json")

    def tearDown(self):
        self.dir.cleanup()

    def test_shim_passes_and_fails_like_the_old_guard(self):
        write_json(self.fresh, {"speedup_at_4": 1.4})
        write_json(self.base, {"speedup_at_4": 1.5})
        rc = ctrl_plane_guard.main(["prog", self.fresh, self.base, "--tolerance", "0.30"])
        self.assertEqual(rc, 0)
        write_json(self.fresh, {"speedup_at_4": 0.9})
        rc = ctrl_plane_guard.main(["prog", self.fresh, self.base, "--tolerance", "0.30"])
        self.assertEqual(rc, 1)

    def test_shim_pending_flow(self):
        write_json(self.fresh, {"speedup_at_4": 1.4})
        write_json(self.base, {"pending": True, "speedup_at_4": None})
        rc = ctrl_plane_guard.main(["prog", self.fresh, self.base])
        self.assertEqual(rc, 1, "pending hard-fails without --refresh-pending")
        rc = ctrl_plane_guard.main(["prog", self.fresh, self.base, "--refresh-pending"])
        self.assertEqual(rc, 0)
        with open(self.base) as f:
            self.assertEqual(json.load(f)["speedup_at_4"], 1.4)

    def test_shim_refuses_promoting_regressed_run(self):
        write_json(self.fresh, {"speedup_at_4": 0.5})
        write_json(self.base, {"pending": True, "speedup_at_4": None})
        rc = ctrl_plane_guard.main(["prog", self.fresh, self.base, "--refresh-pending"])
        self.assertEqual(rc, 1)

    def test_shim_usage_errors(self):
        self.assertEqual(ctrl_plane_guard.main(["prog"]), 2)
        self.assertEqual(ctrl_plane_guard.main(["prog", "--bogus"]), 2)
        self.assertEqual(
            ctrl_plane_guard.main(["prog", "x.json", "--tolerance", "abc"]), 2
        )


class RepoManifestTests(unittest.TestCase):
    """Pin the committed manifest's event_scale entry: the wall-clock
    acceptance bound (2,000 workers / 1M tasks under 30 s) must stay an
    absolute ceiling, not a baseline-relative drift band."""

    def test_event_scale_entry_is_a_30s_wall_clock_ceiling(self):
        path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            os.pardir,
            "rust",
            "benches",
            "baselines",
            "manifest.json",
        )
        with open(path) as f:
            spec = json.load(f)["benches"]["event_scale"]
        self.assertEqual(spec["fresh"], "BENCH_event_scale.json")
        self.assertEqual(spec["metric"], "wall_s_2000w_1m")
        self.assertEqual(spec["direction"], "lower")
        self.assertEqual(spec["check"], "min_delta")
        self.assertEqual(spec["min_delta"], 30.0)
        self.assertEqual(spec["min_to_promote"], 30.0)

    def test_store_read_heavy_entry_is_a_ratcheted_floor(self):
        path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            os.pardir,
            "rust",
            "benches",
            "baselines",
            "manifest.json",
        )
        with open(path) as f:
            spec = json.load(f)["benches"]["store_read_heavy"]
        self.assertEqual(spec["fresh"], "BENCH_store_throughput.json")
        self.assertEqual(spec["metric"], "ops_per_sec_read_heavy_16t")
        self.assertEqual(spec["direction"], "higher")
        self.assertEqual(spec["check"], "ratchet")
        self.assertEqual(spec["config_keys"], ["ops_per_thread"])


if __name__ == "__main__":
    unittest.main()
