#!/usr/bin/env python3
"""CI timing guard for the ctrl_plane bench.

Compares a fresh BENCH_ctrl_plane.json against the committed baseline
(rust/benches/baselines/ctrl_plane.json) and fails if the home-routed
control plane's throughput advantage regressed by more than the
tolerance (default 30%).

The guarded metric is `speedup_at_4` — HomeRouted tasks/sec divided by
Broadcast tasks/sec at 4 workers *within the same run*. Guarding the
ratio rather than absolute tasks/sec keeps the check meaningful across
heterogeneous CI machines: both modes run on the same box, so the ratio
cancels the machine out.

A baseline with `"pending": true` is a HARD FAILURE: a pending baseline
guards nothing. The CI bench-smoke job refreshes a pending baseline from
the fresh run (`--refresh-pending`, committed back on pushes to main)
*before* invoking the guard, so the only way to see this failure is an
unrefreshed checkout — fix it by running
`cargo bench --bench ctrl_plane` and copying BENCH_ctrl_plane.json over
rust/benches/baselines/ctrl_plane.json.

Usage: ctrl_plane_guard.py <fresh.json> [baseline.json]
           [--tolerance 0.30] [--refresh-pending]
"""

import json
import sys


def main(argv):
    args = []
    tol = 0.30
    refresh_pending = False
    rest = iter(argv[1:])
    for a in rest:
        if a == "--tolerance" or a.startswith("--tolerance="):
            raw = a.split("=", 1)[1] if "=" in a else next(rest, None)
            try:
                tol = float(raw)
            except (TypeError, ValueError):
                print(f"--tolerance needs a numeric value, got {raw!r}")
                return 2
        elif a == "--refresh-pending":
            refresh_pending = True
        elif a.startswith("--"):
            print(f"unknown flag: {a}")
            return 2
        else:
            args.append(a)
    if not args:
        print(__doc__)
        return 2
    fresh_path = args[0]
    base_path = args[1] if len(args) > 1 else "rust/benches/baselines/ctrl_plane.json"

    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)

    fresh_speedup = float(fresh["speedup_at_4"])
    if base.get("pending"):
        if refresh_pending:
            # Never promote a run that shows HomeRouted slower than
            # Broadcast beyond tolerance: enshrining a regressed run as
            # the baseline would mask the regression forever.
            floor = 1.0 * (1.0 - tol)
            if fresh_speedup < floor:
                print(
                    f"FAIL: refusing to promote a regressed run as baseline: "
                    f"speedup_at_4 {fresh_speedup:.3f} < parity floor {floor:.3f}"
                )
                return 1
            # Promote the fresh run's real numbers to be the baseline.
            with open(fresh_path) as f, open(base_path, "w") as out:
                out.write(f.read())
            print(
                f"baseline was pending: refreshed {base_path} from {fresh_path} "
                f"(speedup_at_4 {fresh_speedup:.3f}); commit it to make this stick"
            )
            base = fresh
        else:
            print(
                "FAIL: the committed baseline is still 'pending': true — it guards "
                "nothing. Run `cargo bench --bench ctrl_plane` and copy "
                f"BENCH_ctrl_plane.json over {base_path} (CI does this "
                "automatically via --refresh-pending on pushes to main)."
            )
            return 1

    base_speedup = float(base["speedup_at_4"])
    floor = base_speedup * (1.0 - tol)
    print(
        f"speedup_at_4: fresh {fresh_speedup:.3f} vs baseline {base_speedup:.3f} "
        f"(floor {floor:.3f}, tolerance {tol:.0%})"
    )
    if fresh_speedup < floor:
        print("FAIL: ctrl_plane throughput advantage regressed beyond tolerance")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
