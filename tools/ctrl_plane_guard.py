#!/usr/bin/env python3
"""CI timing guard for the ctrl_plane bench.

Compares a fresh BENCH_ctrl_plane.json against the committed baseline
(rust/benches/baselines/ctrl_plane.json) and fails if the home-routed
control plane's throughput advantage regressed by more than the
tolerance (default 30%).

The guarded metric is `speedup_at_4` — HomeRouted tasks/sec divided by
Broadcast tasks/sec at 4 workers *within the same run*. Guarding the
ratio rather than absolute tasks/sec keeps the check meaningful across
heterogeneous CI machines: both modes run on the same box, so the ratio
cancels the machine out.

A baseline with `"pending": true` (no toolchain was available to the
authoring environment) guards against parity instead: the fresh run must
not show HomeRouted *slower* than Broadcast beyond the tolerance. CI
should then refresh the baseline from its uploaded artifact.

Usage: ctrl_plane_guard.py <fresh.json> [baseline.json] [--tolerance 0.30]
"""

import json
import sys


def main(argv):
    args = []
    tol = 0.30
    rest = iter(argv[1:])
    for a in rest:
        if a == "--tolerance" or a.startswith("--tolerance="):
            raw = a.split("=", 1)[1] if "=" in a else next(rest, None)
            try:
                tol = float(raw)
            except (TypeError, ValueError):
                print(f"--tolerance needs a numeric value, got {raw!r}")
                return 2
        elif a.startswith("--"):
            print(f"unknown flag: {a}")
            return 2
        else:
            args.append(a)
    if not args:
        print(__doc__)
        return 2
    fresh_path = args[0]
    base_path = args[1] if len(args) > 1 else "rust/benches/baselines/ctrl_plane.json"

    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)

    fresh_speedup = float(fresh["speedup_at_4"])
    if base.get("pending"):
        floor = 1.0 * (1.0 - tol)
        print(
            f"baseline is pending (authored without a Rust toolchain); "
            f"guarding against parity: speedup_at_4 {fresh_speedup:.3f} "
            f"must be >= {floor:.3f}"
        )
        if fresh_speedup < floor:
            print("FAIL: home-routed plane is slower than broadcast beyond tolerance")
            return 1
        print("OK — refresh the committed baseline from this run's artifact")
        return 0

    base_speedup = float(base["speedup_at_4"])
    floor = base_speedup * (1.0 - tol)
    print(
        f"speedup_at_4: fresh {fresh_speedup:.3f} vs baseline {base_speedup:.3f} "
        f"(floor {floor:.3f}, tolerance {tol:.0%})"
    )
    if fresh_speedup < floor:
        print("FAIL: ctrl_plane throughput advantage regressed beyond tolerance")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
