#!/usr/bin/env python3
"""Back-compat shim: CI timing guard for the ctrl_plane bench.

The real logic now lives in tools/bench_guard.py (manifest-driven, one
guard for every bench). This shim preserves the historical CLI so old
invocations and docs keep working:

    ctrl_plane_guard.py <fresh.json> [baseline.json]
        [--tolerance 0.30] [--refresh-pending]

Semantics are unchanged: the guarded metric is `speedup_at_4`
(higher-is-better), a pending baseline hard-fails unless
--refresh-pending promotes the fresh run, and promotion refuses runs
below the parity floor `1.0 * (1 - tolerance)`.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_guard  # noqa: E402


def main(argv):
    args = []
    tol = 0.30
    refresh_pending = False
    rest = iter(argv[1:])
    for a in rest:
        if a == "--tolerance" or a.startswith("--tolerance="):
            raw = a.split("=", 1)[1] if "=" in a else next(rest, None)
            try:
                tol = float(raw)
            except (TypeError, ValueError):
                print(f"--tolerance needs a numeric value, got {raw!r}")
                return 2
        elif a == "--refresh-pending":
            refresh_pending = True
        elif a.startswith("--"):
            print(f"unknown flag: {a}")
            return 2
        else:
            args.append(a)
    if not args:
        print(__doc__)
        return 2
    fresh_path = args[0]
    base_path = args[1] if len(args) > 1 else "rust/benches/baselines/ctrl_plane.json"
    ok = bench_guard.guard_one(
        "ctrl_plane",
        fresh_path=fresh_path,
        base_path=base_path,
        metric="speedup_at_4",
        direction="higher",
        tolerance=tol,
        min_to_promote=1.0 * (1.0 - tol),
        refresh_pending=refresh_pending,
    )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
