"""Layer-2 task pipelines: the jax compute graphs behind each engine task.

Each function here is one *task type* the Rust engine schedules. They
compose the Layer-1 Pallas kernels and are AOT-lowered by ``aot.py`` to
HLO text, one artifact per (task type, block length). The Rust runtime
(`rust/src/runtime/`) compiles each artifact once per process and
executes it on the request path — Python is never invoked at runtime.

Every pipeline returns a flat tuple of arrays; the last output is always
the f32 stats/checksum vector the engine records per task.
"""

import jax.numpy as jnp

from .kernels import (
    coalesce_copy,
    scale_shift,
    hash_partition_ids,
    window_sum,
    zip_pack,
    zip_stats,
)

#: Shuffle fan-out used by the partition task (fixed at AOT time).
NUM_PARTS = 32


def zip_task(a, b):
    """The paper's zip task (Fig 2): C_i = zip(A_i, B_i) plus fused stats.

    Returns ``(kv f32[n, 2], stats f32[4])``.
    """
    kv = zip_pack(a, b)
    stats = zip_stats(a, b)
    return kv, stats


def coalesce_task(a, b):
    """The paper's coalesce task (Fig 1): x = a ++ b plus a checksum.

    Returns ``(merged f32[na + nb], stats f32[4])``.
    """
    merged = coalesce_copy(a, b)
    stats = zip_stats(a, b)
    return merged, stats


def agg_task(x):
    """Reduce-style task: windowed partial sums plus a global checksum.

    Returns ``(partials f32[n // 128], stats f32[4])``.
    """
    partials = window_sum(x)
    stats = zip_stats(x, x)
    return partials, stats


def partition_task(x):
    """Shuffle map-side task: partition ids and per-partition counts.

    Returns ``(ids i32[n], counts f32[NUM_PARTS], stats f32[4])``.
    """
    ids = hash_partition_ids(x, NUM_PARTS)
    one_hot = jnp.zeros((NUM_PARTS,), jnp.float32).at[ids].add(1.0)
    stats = zip_stats(x, x)
    return ids, one_hot, stats


def map_task(x):
    """Elementwise map task: affine transform plus a checksum.

    Returns ``(mapped f32[n], stats f32[4])``.
    """
    mapped = scale_shift(x)
    stats = zip_stats(x, x)
    return mapped, stats


def zip_reduce_task(a, b):
    """Fused zip → windowed reduce over the values, keyed by block a.

    The downstream stage of a two-stage zip job: consumes both peers and
    emits the reduced values. Returns ``(reduced f32[n // 128], stats f32[4])``.
    """
    kv = zip_pack(a, b)
    # Reduce the value lane of the packed kv pairs window-by-window.
    values = kv[:, 1]
    reduced = window_sum(values)
    stats = zip_stats(a, b)
    return reduced, stats


#: Registry consumed by aot.py: name -> (fn, arity). All inputs are
#: f32[n] blocks of the same length n.
TASKS = {
    "zip_task": (zip_task, 2),
    "coalesce_task": (coalesce_task, 2),
    "agg_task": (agg_task, 1),
    "partition_task": (partition_task, 1),
    "zip_reduce_task": (zip_reduce_task, 2),
    "map_task": (map_task, 1),
}
