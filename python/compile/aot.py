"""AOT compile path: lower every Layer-2 task pipeline to HLO text.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.

Outputs, per (task, block length n):
    artifacts/<task>_<n>.hlo.txt
plus a single ``artifacts/manifest.json`` describing every artifact's
entry point, input arity/shapes and output shapes — the Rust runtime
reads the manifest instead of hard-coding shapes.

Usage: ``python -m compile.aot --out-dir ../artifacts [--sizes 4096,65536]``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import NUM_PARTS, TASKS

DEFAULT_SIZES = (4096, 8192, 65536, 131072)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_task(name: str, n: int):
    fn, arity = TASKS[name]
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(fn).lower(*([spec] * arity))
    return lowered, arity


def shape_entry(aval) -> dict:
    return {"dtype": str(aval.dtype), "shape": list(aval.shape)}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated block lengths (f32 elements) to AOT",
    )
    args = parser.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"num_parts": NUM_PARTS, "artifacts": []}
    for name in TASKS:
        for n in sizes:
            lowered, arity = lower_task(name, n)
            text = to_hlo_text(lowered)
            fname = f"{name}_{n}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            out_avals = jax.tree_util.tree_leaves(lowered.out_info)
            manifest["artifacts"].append(
                {
                    "task": name,
                    "block_len": n,
                    "file": fname,
                    "arity": arity,
                    "inputs": [shape_entry(jax.ShapeDtypeStruct((n,), jnp.float32))] * arity,
                    "outputs": [shape_entry(o) for o in out_avals],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")

    # TSV twin of the JSON manifest: the Rust runtime is built offline
    # without a JSON dependency, so it parses this line format instead.
    # Columns: task  block_len  file  arity  outputs
    # where outputs = dtype:dim,dim|dtype:dim ...
    tsv_path = os.path.join(args.out_dir, "manifest.tsv")
    with open(tsv_path, "w") as f:
        f.write(f"# lerc-engine artifact manifest; num_parts={NUM_PARTS}\n")
        for e in manifest["artifacts"]:
            outs = "|".join(
                f"{o['dtype']}:{','.join(str(d) for d in o['shape'])}"
                for o in e["outputs"]
            )
            f.write(
                f"{e['task']}\t{e['block_len']}\t{e['file']}\t{e['arity']}\t{outs}\n"
            )
    print(f"wrote {tsv_path}")


if __name__ == "__main__":
    main()
