"""Pallas kernel: compute shuffle partition ids for a block.

Models the map-side of a shuffle: each element is hashed (a 32-bit
integer mix of its bit pattern) and assigned to one of ``num_parts``
partitions. Integer bit ops run on the VPU; the kernel is element-wise
and bandwidth-bound.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .zip_pack import LANES, SUBLANES, TILE


def _mix32(h):
    # fmix32 finalizer from MurmurHash3 — a full-avalanche 32-bit mix.
    h = h ^ (h >> 16)
    h = h * jnp.int32(-2048144789)  # 0x85ebca6b
    h = h ^ (h >> 13)
    h = h * jnp.int32(-1028477387)  # 0xc2b2ae35
    h = h ^ (h >> 16)
    return h


def _hash_kernel(num_parts, x_ref, o_ref):
    bits = x_ref[...].view(jnp.int32)
    h = _mix32(bits)
    o_ref[...] = jnp.abs(h % jnp.int32(num_parts))


def hash_partition_ids(x: jax.Array, num_parts: int = 32) -> jax.Array:
    """Partition id in [0, num_parts) for each element of ``x`` -> i32[n]."""
    n = x.shape[0]
    assert n % TILE == 0
    rows = n // LANES
    grid = rows // SUBLANES
    x2 = x.reshape(rows, LANES)

    out = pl.pallas_call(
        functools.partial(_hash_kernel, num_parts),
        grid=(grid,),
        in_specs=[pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=True,
    )(x2)
    return out.reshape(n)
