"""Pallas kernel: zip two equal-length f32 blocks into key-value pairs.

This is the compute core of the paper's ``zip`` task (Fig 2): block
``C_i = zip(A_i, B_i)``, i.e. ``out[j] = (a[j], b[j])``.

Tiling: the 1-D block of ``n`` floats is viewed as ``(n // 128, 128)``
(TPU lane width 128) and scheduled in row tiles of 8 (sublane width), so
each grid step moves one (8, 128) tile of keys and one of values into
VMEM and writes an (8, 128, 2) tile out. VMEM footprint per step:
3 tiles * 4 KiB = 12 KiB, far under the 16 MiB budget, leaving room for
double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 128
TILE = SUBLANES * LANES  # 1024 elements per grid step


def _zip_pack_kernel(a_ref, b_ref, o_ref):
    # o[..., 0] = keys, o[..., 1] = values. Stack along a new minor axis;
    # on TPU this is a pure VMEM relayout feeding the DMA back to HBM.
    o_ref[...] = jnp.stack([a_ref[...], b_ref[...]], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def zip_pack(a: jax.Array, b: jax.Array) -> jax.Array:
    """Zip ``a`` (keys) with ``b`` (values) -> f32[n, 2].

    ``n`` must be a multiple of 1024 (one (8, 128) tile).
    """
    n = a.shape[0]
    assert n % TILE == 0, f"block length {n} not a multiple of {TILE}"
    rows = n // LANES
    grid = rows // SUBLANES

    a2 = a.reshape(rows, LANES)
    b2 = b.reshape(rows, LANES)

    out = pl.pallas_call(
        _zip_pack_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES, 2), jnp.float32),
        interpret=True,
    )(a2, b2)
    return out.reshape(n, 2)
