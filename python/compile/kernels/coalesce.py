"""Pallas kernel: coalesce (concatenate) two f32 blocks into one.

The paper's Fig 1 example is a ``coalesce`` task: block ``x = a ++ b``.
The kernel is a tiled VMEM copy — each grid step DMAs one (8, 128) tile
of each input into VMEM and writes it straight out; the halves are
joined at Layer 2 with a zero-cost ``concatenate`` that XLA fuses into
the output layout.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .zip_pack import LANES, SUBLANES, TILE


def _copy2_kernel(a_ref, b_ref, o1_ref, o2_ref):
    o1_ref[...] = a_ref[...]
    o2_ref[...] = b_ref[...]


def coalesce_copy(a: jax.Array, b: jax.Array) -> jax.Array:
    """Concatenate ``a`` and ``b`` -> f32[len(a) + len(b)].

    Both inputs must be multiples of 1024 elements; they need not be the
    same length as each other.
    """
    na, nb = a.shape[0], b.shape[0]
    assert na % TILE == 0 and nb % TILE == 0
    # Pad the shorter input's grid by clamping its index map so every grid
    # step has a valid tile to read; the clamped duplicate rows are never
    # written to a fresh output location.
    rows_a, rows_b = na // LANES, nb // LANES
    grid = max(rows_a, rows_b) // SUBLANES
    ga, gb = rows_a // SUBLANES, rows_b // SUBLANES

    a2 = a.reshape(rows_a, LANES)
    b2 = b.reshape(rows_b, LANES)

    o1, o2 = pl.pallas_call(
        _copy2_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i, ga=ga: (jnp.minimum(i, ga - 1), 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i, gb=gb: (jnp.minimum(i, gb - 1), 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i, ga=ga: (jnp.minimum(i, ga - 1), 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i, gb=gb: (jnp.minimum(i, gb - 1), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows_a, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows_b, LANES), jnp.float32),
        ],
        interpret=True,
    )(a2, b2)
    return jnp.concatenate([o1.reshape(na), o2.reshape(nb)])
