"""Pallas kernel: elementwise affine map (the engine's `map` operator).

``out = scale * x + shift`` with constants fixed at AOT time — the
simplest representative of Spark's elementwise map/filter family. Pure
VPU work; roofline is the HBM read+write of the block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .zip_pack import LANES, SUBLANES, TILE


def _scale_shift_kernel(scale, shift, x_ref, o_ref):
    o_ref[...] = x_ref[...] * scale + shift


def scale_shift(x: jax.Array, scale: float = 0.5, shift: float = 1.0) -> jax.Array:
    """Affine map of a block -> f32[n]."""
    n = x.shape[0]
    assert n % TILE == 0
    rows = n // LANES
    grid = rows // SUBLANES
    x2 = x.reshape(rows, LANES)

    out = pl.pallas_call(
        # Plain Python floats fold into the kernel as compile-time
        # immediates (traced jnp scalars would be captured constants,
        # which pallas rejects).
        functools.partial(_scale_shift_kernel, float(scale), float(shift)),
        grid=(grid,),
        in_specs=[pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(n)
