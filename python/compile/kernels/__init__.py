"""Layer-1 Pallas kernels for lerc-engine compute tasks.

Every kernel is written with TPU-shaped tiling — (8, 128) lane-aligned
blocks scheduled through BlockSpec — but lowered with ``interpret=True``
so the resulting HLO runs on any PJRT backend (the Rust CPU client in
this repo). See DESIGN.md §Hardware-Adaptation.
"""

from .zip_pack import zip_pack
from .coalesce import coalesce_copy
from .window_sum import window_sum
from .hash_partition import hash_partition_ids
from .scale_shift import scale_shift
from .zip_stats import zip_stats

__all__ = [
    "zip_pack",
    "coalesce_copy",
    "window_sum",
    "hash_partition_ids",
    "zip_stats",
    "scale_shift",
]
