"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

These are the ground truth the pytest/hypothesis suite checks the
kernels against (``assert_allclose``). Keep them boring: no tiling, no
pallas, just the mathematical definition.
"""

import jax.numpy as jnp


def zip_pack_ref(a, b):
    """out[j] = (a[j], b[j]) -> f32[n, 2]."""
    return jnp.stack([a, b], axis=-1)


def coalesce_copy_ref(a, b):
    """out = a ++ b -> f32[len(a) + len(b)]."""
    return jnp.concatenate([a, b])


def window_sum_ref(x):
    """Sum of each consecutive 128-element window -> f32[n // 128]."""
    return jnp.sum(x.reshape(-1, 128), axis=1)


def _mix32_ref(h):
    h = h ^ (h >> 16)
    h = h * jnp.int32(-2048144789)
    h = h ^ (h >> 13)
    h = h * jnp.int32(-1028477387)
    h = h ^ (h >> 16)
    return h


def hash_partition_ids_ref(x, num_parts=32):
    """MurmurHash3 fmix32 of the bit pattern, mod num_parts -> i32[n]."""
    return jnp.abs(_mix32_ref(x.view(jnp.int32)) % jnp.int32(num_parts))


def scale_shift_ref(x, scale=0.5, shift=1.0):
    """out = scale * x + shift -> f32[n]."""
    return x * jnp.float32(scale) + jnp.float32(shift)


def zip_stats_ref(a, b):
    """[dot(a, b), sum(a), sum(b), max(|a| + |b|)] -> f32[4]."""
    return jnp.array(
        [
            jnp.sum(a * b),
            jnp.sum(a),
            jnp.sum(b),
            jnp.max(jnp.abs(a) + jnp.abs(b)),
        ],
        jnp.float32,
    )
