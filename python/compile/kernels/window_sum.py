"""Pallas kernel: windowed (per-128-lane row) sum reduction.

Models the aggregation half of a reduce-style task: every 128-element
window of the block collapses to one partial sum. This is a VPU-bound
kernel (lane reduction, no MXU); roofline is the HBM read of the input.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .zip_pack import LANES, SUBLANES, TILE


def _window_sum_kernel(x_ref, o_ref):
    # Reduce across lanes; keep the sublane axis so the output stays
    # 2-D-tileable ((8, 1) tiles).
    o_ref[...] = jnp.sum(x_ref[...], axis=1, keepdims=True)


def window_sum(x: jax.Array) -> jax.Array:
    """Sum each consecutive 128-wide window of ``x`` -> f32[n // 128]."""
    n = x.shape[0]
    assert n % TILE == 0
    rows = n // LANES
    grid = rows // SUBLANES
    x2 = x.reshape(rows, LANES)

    out = pl.pallas_call(
        _window_sum_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((SUBLANES, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(rows)
