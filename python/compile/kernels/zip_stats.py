"""Pallas kernel: fused statistics over a zipped (key, value) block pair.

Produces ``[dot(a, b), sum(a), sum(b), max(|a| + |b|)]`` in one pass —
the per-task "result summary" checksum the Rust engine records for each
zip task. Demonstrates cross-grid-step accumulation: the output tile is
revisited by every grid step (constant index map) and accumulated, with
initialization gated on the first step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .zip_pack import LANES, SUBLANES, TILE

STATS = 4


def _zip_stats_kernel(a_ref, b_ref, o_ref):
    i = pl.program_id(0)
    a = a_ref[...]
    b = b_ref[...]
    dot = jnp.sum(a * b)
    sa = jnp.sum(a)
    sb = jnp.sum(b)
    mx = jnp.max(jnp.abs(a) + jnp.abs(b))

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros((1, STATS), jnp.float32)

    prev = o_ref[...]
    acc = jnp.array([[dot, sa, sb, 0.0]], jnp.float32) + prev
    acc = acc.at[0, 3].set(jnp.maximum(prev[0, 3], mx))
    o_ref[...] = acc


def zip_stats(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused [dot, sum_a, sum_b, max(|a|+|b|)] -> f32[4]."""
    n = a.shape[0]
    assert n % TILE == 0
    rows = n // LANES
    grid = rows // SUBLANES
    a2 = a.reshape(rows, LANES)
    b2 = b.reshape(rows, LANES)

    out = pl.pallas_call(
        _zip_stats_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        # Every grid step maps to the same (1, 4) output tile -> accumulate.
        out_specs=pl.BlockSpec((1, STATS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, STATS), jnp.float32),
        interpret=True,
    )(a2, b2)
    return out.reshape(STATS)
