"""AOT pipeline tests: HLO text emission, manifest integrity, and a
round-trip execution of the emitted HLO on the local CPU backend (the
same text the Rust PJRT client loads)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref

TILE = 1024


def test_to_hlo_text_smoke():
    lowered, arity = aot.lower_task("zip_task", TILE)
    assert arity == 2
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[1024]" in text


@pytest.mark.parametrize("name", sorted(model.TASKS))
def test_every_task_emits_hlo(name):
    lowered, _ = aot.lower_task(name, TILE)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # ROOT tuple is required for the rust loader's to_tuple unwrap.
    assert "ROOT" in text


def test_main_writes_manifest(tmp_path, monkeypatch):
    out = tmp_path / "arts"
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(out), "--sizes", str(TILE)],
    )
    aot.main()
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["num_parts"] == model.NUM_PARTS
    assert len(manifest["artifacts"]) == len(model.TASKS)
    for entry in manifest["artifacts"]:
        path = out / entry["file"]
        assert path.exists(), entry["file"]
        assert entry["arity"] == len(entry["inputs"])
        assert entry["block_len"] == TILE
        assert all(i["dtype"] == "float32" for i in entry["inputs"])
        assert len(entry["outputs"]) >= 2  # payload(s) + stats


def test_zip_task_numerics_via_compiled_path():
    """Execute the jitted (same XLA program as the artifact) zip_task and
    compare against the oracle. The text-load path itself is exercised
    authoritatively from Rust (rust/src/runtime tests)."""
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.normal(size=TILE).astype(np.float32))
    b = jnp.asarray(rng.normal(size=TILE).astype(np.float32))
    kv, stats = jax.jit(model.zip_task)(a, b)
    assert_allclose(np.asarray(kv), np.asarray(ref.zip_pack_ref(a, b)))
    assert_allclose(
        np.asarray(stats), np.asarray(ref.zip_stats_ref(a, b)), rtol=1e-4, atol=1e-3
    )


def test_manifest_shapes_consistent_with_model():
    lowered, _ = aot.lower_task("partition_task", TILE)
    outs = jax.tree_util.tree_leaves(lowered.out_info)
    shapes = [tuple(o.shape) for o in outs]
    assert shapes == [(TILE,), (model.NUM_PARTS,), (4,)]
