"""Kernel-vs-oracle correctness: every Pallas kernel against ref.py.

Hypothesis sweeps block lengths (multiples of the 1024-element tile) and
value distributions; fixed seeds keep the suite deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    coalesce_copy,
    hash_partition_ids,
    window_sum,
    zip_pack,
    zip_stats,
)
from compile.kernels import ref

TILE = 1024
SIZES = [TILE, 2 * TILE, 4 * TILE, 16 * TILE]


def rand(n, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=n, scale=scale).astype(np.float32))


# ---------------------------------------------------------------- zip_pack


@pytest.mark.parametrize("n", SIZES)
def test_zip_pack_matches_ref(n):
    a, b = rand(n, 1), rand(n, 2)
    assert_allclose(np.asarray(zip_pack(a, b)), np.asarray(ref.zip_pack_ref(a, b)))


def test_zip_pack_shape_and_dtype():
    a, b = rand(TILE), rand(TILE)
    out = zip_pack(a, b)
    assert out.shape == (TILE, 2)
    assert out.dtype == jnp.float32


def test_zip_pack_keys_then_values():
    a, b = rand(TILE, 3), rand(TILE, 4)
    out = np.asarray(zip_pack(a, b))
    assert_allclose(out[:, 0], np.asarray(a))
    assert_allclose(out[:, 1], np.asarray(b))


def test_zip_pack_rejects_unaligned():
    with pytest.raises(AssertionError):
        zip_pack(rand(1000), rand(1000))


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-3, max_value=1e3),
)
def test_zip_pack_hypothesis(tiles, seed, scale):
    n = tiles * TILE
    a, b = rand(n, seed, scale), rand(n, seed + 1, scale)
    assert_allclose(np.asarray(zip_pack(a, b)), np.asarray(ref.zip_pack_ref(a, b)))


# ------------------------------------------------------------- coalesce


@pytest.mark.parametrize("na,nb", [(TILE, TILE), (2 * TILE, TILE), (TILE, 4 * TILE)])
def test_coalesce_matches_ref(na, nb):
    a, b = rand(na, 5), rand(nb, 6)
    assert_allclose(
        np.asarray(coalesce_copy(a, b)), np.asarray(ref.coalesce_copy_ref(a, b))
    )


def test_coalesce_order():
    a = jnp.ones(TILE, jnp.float32)
    b = jnp.zeros(TILE, jnp.float32)
    out = np.asarray(coalesce_copy(a, b))
    assert out[:TILE].min() == 1.0 and out[TILE:].max() == 0.0


@settings(max_examples=15, deadline=None)
@given(
    ta=st.integers(min_value=1, max_value=6),
    tb=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_coalesce_hypothesis(ta, tb, seed):
    a, b = rand(ta * TILE, seed), rand(tb * TILE, seed + 7)
    assert_allclose(
        np.asarray(coalesce_copy(a, b)), np.asarray(ref.coalesce_copy_ref(a, b))
    )


# ------------------------------------------------------------ window_sum


@pytest.mark.parametrize("n", SIZES)
def test_window_sum_matches_ref(n):
    x = rand(n, 8)
    assert_allclose(
        np.asarray(window_sum(x)),
        np.asarray(ref.window_sum_ref(x)),
        rtol=1e-5,
        atol=1e-4,
    )


def test_window_sum_constant():
    x = jnp.full((TILE,), 2.0, jnp.float32)
    assert_allclose(np.asarray(window_sum(x)), np.full(TILE // 128, 256.0))


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_window_sum_hypothesis(tiles, seed):
    x = rand(tiles * TILE, seed)
    assert_allclose(
        np.asarray(window_sum(x)),
        np.asarray(ref.window_sum_ref(x)),
        rtol=1e-5,
        atol=1e-4,
    )


# -------------------------------------------------------- hash_partition


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("parts", [2, 32, 100])
def test_hash_partition_matches_ref(n, parts):
    x = rand(n, 9)
    got = np.asarray(hash_partition_ids(x, parts))
    want = np.asarray(ref.hash_partition_ids_ref(x, parts))
    np.testing.assert_array_equal(got, want)


def test_hash_partition_range():
    x = rand(4 * TILE, 10)
    ids = np.asarray(hash_partition_ids(x, 32))
    assert ids.min() >= 0 and ids.max() < 32
    assert ids.dtype == np.int32


def test_hash_partition_balanced():
    # A full-avalanche hash over gaussian bits should spread reasonably.
    x = rand(16 * TILE, 11)
    counts = np.bincount(np.asarray(hash_partition_ids(x, 16)), minlength=16)
    expected = x.shape[0] / 16
    assert counts.min() > 0.8 * expected and counts.max() < 1.2 * expected


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    parts=st.integers(min_value=1, max_value=257),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hash_partition_hypothesis(tiles, parts, seed):
    x = rand(tiles * TILE, seed)
    got = np.asarray(hash_partition_ids(x, parts))
    want = np.asarray(ref.hash_partition_ids_ref(x, parts))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------- zip_stats


@pytest.mark.parametrize("n", SIZES)
def test_zip_stats_matches_ref(n):
    a, b = rand(n, 12), rand(n, 13)
    assert_allclose(
        np.asarray(zip_stats(a, b)),
        np.asarray(ref.zip_stats_ref(a, b)),
        rtol=1e-4,
        atol=1e-3,
    )


def test_zip_stats_known_values():
    a = jnp.ones(TILE, jnp.float32)
    b = jnp.full((TILE,), 2.0, jnp.float32)
    got = np.asarray(zip_stats(a, b))
    assert_allclose(got, [2.0 * TILE, float(TILE), 2.0 * TILE, 3.0], rtol=1e-6)


def test_zip_stats_accumulates_across_grid():
    # Multiple grid steps must accumulate, not overwrite.
    n = 8 * TILE
    a = jnp.ones(n, jnp.float32)
    b = jnp.ones(n, jnp.float32)
    got = np.asarray(zip_stats(a, b))
    assert_allclose(got[:3], [float(n)] * 3, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-2, max_value=1e2),
)
def test_zip_stats_hypothesis(tiles, seed, scale):
    n = tiles * TILE
    a, b = rand(n, seed, scale), rand(n, seed + 1, scale)
    # dot/sum tolerance scales with n * scale^2 accumulation error.
    assert_allclose(
        np.asarray(zip_stats(a, b)),
        np.asarray(ref.zip_stats_ref(a, b)),
        rtol=1e-3,
        atol=1e-2 * scale * scale * np.sqrt(n),
    )


# ------------------------------------------------------------ scale_shift

from compile.kernels import scale_shift


@pytest.mark.parametrize("n", SIZES)
def test_scale_shift_matches_ref(n):
    x = rand(n, 14)
    assert_allclose(
        np.asarray(scale_shift(x)), np.asarray(ref.scale_shift_ref(x)), rtol=1e-6
    )


def test_scale_shift_constants():
    x = jnp.zeros(TILE, jnp.float32)
    assert_allclose(np.asarray(scale_shift(x)), np.ones(TILE))


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_scale_shift_hypothesis(tiles, seed):
    x = rand(tiles * TILE, seed)
    assert_allclose(
        np.asarray(scale_shift(x)), np.asarray(ref.scale_shift_ref(x)), rtol=1e-6
    )
