"""Manifest integrity: the TSV twin (consumed by the offline Rust build)
must stay bit-consistent with the JSON manifest."""

import json
import sys

import pytest

from compile import aot, model

TILE = 1024


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("arts")
    argv = ["aot", "--out-dir", str(out), "--sizes", f"{TILE},{4 * TILE}"]
    old = sys.argv
    sys.argv = argv
    try:
        aot.main()
    finally:
        sys.argv = old
    return out


def parse_tsv(path):
    rows = {}
    num_parts = None
    for line in path.read_text().splitlines():
        if line.startswith("#"):
            num_parts = int(line.split("num_parts=")[1])
            continue
        task, block_len, fname, arity, outs = line.split("\t")
        outputs = []
        for spec in outs.split("|"):
            dtype, dims = spec.split(":")
            outputs.append(
                {"dtype": dtype, "shape": [int(d) for d in dims.split(",") if d]}
            )
        rows[(task, int(block_len))] = {
            "file": fname,
            "arity": int(arity),
            "outputs": outputs,
        }
    return num_parts, rows


def test_tsv_matches_json(built):
    manifest = json.loads((built / "manifest.json").read_text())
    num_parts, rows = parse_tsv(built / "manifest.tsv")
    assert num_parts == manifest["num_parts"] == model.NUM_PARTS
    assert len(rows) == len(manifest["artifacts"])
    for e in manifest["artifacts"]:
        row = rows[(e["task"], e["block_len"])]
        assert row["file"] == e["file"]
        assert row["arity"] == e["arity"]
        assert row["outputs"] == e["outputs"]


def test_every_artifact_file_exists_and_is_hlo(built):
    _, rows = parse_tsv(built / "manifest.tsv")
    for (task, n), row in rows.items():
        text = (built / row["file"]).read_text()
        assert text.startswith("HloModule"), (task, n)
        assert "ROOT" in text


def test_sizes_cover_both_requested(built):
    _, rows = parse_tsv(built / "manifest.tsv")
    lens = {n for (_, n) in rows}
    assert lens == {TILE, 4 * TILE}
    tasks = {t for (t, _) in rows}
    assert tasks == set(model.TASKS)
