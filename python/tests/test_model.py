"""Layer-2 pipeline tests: task outputs, shapes, and registry integrity."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

TILE = 1024
N = 4 * TILE


def rand(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=n).astype(np.float32))


def test_registry_covers_all_tasks():
    assert set(model.TASKS) == {
        "zip_task",
        "coalesce_task",
        "agg_task",
        "partition_task",
        "zip_reduce_task",
        "map_task",
    }
    for name, (fn, arity) in model.TASKS.items():
        assert callable(fn), name
        assert arity in (1, 2), name


def test_zip_task_outputs():
    a, b = rand(N, 1), rand(N, 2)
    kv, stats = model.zip_task(a, b)
    assert kv.shape == (N, 2)
    assert stats.shape == (4,)
    assert_allclose(np.asarray(kv), np.asarray(ref.zip_pack_ref(a, b)))
    assert_allclose(
        np.asarray(stats), np.asarray(ref.zip_stats_ref(a, b)), rtol=1e-4, atol=1e-3
    )


def test_coalesce_task_outputs():
    a, b = rand(N, 3), rand(N, 4)
    merged, stats = model.coalesce_task(a, b)
    assert merged.shape == (2 * N,)
    assert_allclose(np.asarray(merged), np.asarray(ref.coalesce_copy_ref(a, b)))
    assert stats.shape == (4,)


def test_agg_task_outputs():
    x = rand(N, 5)
    partials, stats = model.agg_task(x)
    assert partials.shape == (N // 128,)
    assert_allclose(
        np.asarray(partials), np.asarray(ref.window_sum_ref(x)), rtol=1e-5, atol=1e-4
    )
    # stats for (x, x): dot = sum(x^2)
    assert_allclose(
        float(stats[0]), float(jnp.sum(x * x)), rtol=1e-4
    )


def test_partition_task_outputs():
    x = rand(N, 6)
    ids, counts, stats = model.partition_task(x)
    assert ids.shape == (N,)
    assert counts.shape == (model.NUM_PARTS,)
    # counts must be the histogram of ids and sum to n.
    hist = np.bincount(np.asarray(ids), minlength=model.NUM_PARTS).astype(np.float32)
    assert_allclose(np.asarray(counts), hist)
    assert float(counts.sum()) == N


def test_map_task_outputs():
    x = rand(N, 9)
    mapped, stats = model.map_task(x)
    assert mapped.shape == (N,)
    assert_allclose(
        np.asarray(mapped), np.asarray(ref.scale_shift_ref(x)), rtol=1e-6
    )
    assert stats.shape == (4,)


def test_zip_reduce_task_outputs():
    a, b = rand(N, 7), rand(N, 8)
    reduced, stats = model.zip_reduce_task(a, b)
    assert reduced.shape == (N // 128,)
    # zip then reduce-values == window_sum(b)
    assert_allclose(
        np.asarray(reduced), np.asarray(ref.window_sum_ref(b)), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("name", sorted(model.TASKS))
def test_all_tasks_jit_lower(name):
    """Every registered task must lower AOT — this is the compile gate."""
    import jax

    fn, arity = model.TASKS[name]
    spec = jax.ShapeDtypeStruct((TILE,), jnp.float32)
    lowered = jax.jit(fn).lower(*([spec] * arity))
    assert lowered.compiler_ir("stablehlo") is not None
